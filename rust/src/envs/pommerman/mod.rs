//! Pommerman (NeurIPS-2018 competition rules, re-implemented).
//!
//! 11x11 board, 4 agents, bombs with chained explosions, rigid/wooden
//! walls, power-ups (extra ammo / blast range / kick), 800-step tie.
//! Modes: FFA (everyone for themselves) and Team (0,2 vs 1,3 — the
//! paper's §4.3 experiment).  Observations are 9x9 egocentric fogged
//! views + self attributes, exactly the encoding in
//! python/compile/envs_spec.py (9*9*12 + 8 = 980 features).
//!
//! The engine is deterministic given the seed: board layout, item
//! placement and tie-breaking all come from one PCG stream.

pub mod agents;

use super::{Info, MultiAgentEnv, Step};
use crate::util::rng::Pcg32;

pub const SIZE: usize = 11;
pub const VIEW: usize = 9;
pub const MAX_STEPS: usize = 800;
pub const BOMB_LIFE: i32 = 9;
pub const FLAME_LIFE: i32 = 2;
pub const DEFAULT_BLAST: i32 = 2;
pub const DEFAULT_AMMO: i32 = 1;
pub const OBS_DIM: usize = VIEW * VIEW * 12 + 8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cell {
    Passage,
    Rigid,
    Wood,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ItemKind {
    ExtraBomb,
    IncrRange,
    Kick,
}

#[derive(Clone, Copy, Debug)]
pub struct Bomb {
    pub pos: (i32, i32),
    pub owner: usize,
    pub timer: i32,
    pub blast: i32,
    /// kick velocity (dx, dy); (0,0) when at rest
    pub vel: (i32, i32),
}

#[derive(Clone, Copy, Debug)]
pub struct AgentState {
    pub pos: (i32, i32),
    pub ammo: i32,
    pub blast: i32,
    pub can_kick: bool,
    pub alive: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Ffa,
    Team,
}

/// Actions (paper §4.3): Idle, Up, Down, Left, Right, Bomb.
pub const ACT_IDLE: usize = 0;
pub const ACT_UP: usize = 1;
pub const ACT_DOWN: usize = 2;
pub const ACT_LEFT: usize = 3;
pub const ACT_RIGHT: usize = 4;
pub const ACT_BOMB: usize = 5;

pub fn action_delta(a: usize) -> (i32, i32) {
    match a {
        ACT_UP => (0, -1),
        ACT_DOWN => (0, 1),
        ACT_LEFT => (-1, 0),
        ACT_RIGHT => (1, 0),
        _ => (0, 0),
    }
}

pub struct Pommerman {
    pub mode: Mode,
    rng: Pcg32,
    seed: u64,
    pub board: Vec<Cell>,
    pub items: Vec<Option<ItemKind>>, // revealed items on passage cells
    hidden: Vec<Option<ItemKind>>,    // items hidden under wood
    pub bombs: Vec<Bomb>,
    pub flames: Vec<i32>, // per-cell flame timer (0 = none)
    pub agents: [AgentState; 4],
    pub steps: usize,
    done: bool,
    /// dense shaping rewards on top of the win/loss signal (training aid)
    pub shaping: bool,
}

fn idx(x: i32, y: i32) -> usize {
    debug_assert!(in_bounds(x, y));
    y as usize * SIZE + x as usize
}

pub fn in_bounds(x: i32, y: i32) -> bool {
    (0..SIZE as i32).contains(&x) && (0..SIZE as i32).contains(&y)
}

impl Pommerman {
    pub fn team(seed: u64) -> Self {
        Self::new(seed, Mode::Team)
    }
    pub fn ffa(seed: u64) -> Self {
        Self::new(seed, Mode::Ffa)
    }

    pub fn new(seed: u64, mode: Mode) -> Self {
        let mut env = Pommerman {
            mode,
            rng: Pcg32::from_label(seed, "pommerman"),
            seed,
            board: vec![Cell::Passage; SIZE * SIZE],
            items: vec![None; SIZE * SIZE],
            hidden: vec![None; SIZE * SIZE],
            bombs: Vec::new(),
            flames: vec![0; SIZE * SIZE],
            agents: [AgentState {
                pos: (0, 0),
                ammo: DEFAULT_AMMO,
                blast: DEFAULT_BLAST,
                can_kick: false,
                alive: true,
            }; 4],
            steps: 0,
            done: true,
            shaping: true,
        };
        env.generate();
        env
    }

    /// Teammate of `i` in Team mode (0<->2, 1<->3).
    pub fn teammate(i: usize) -> usize {
        (i + 2) % 4
    }
    pub fn same_team(&self, a: usize, b: usize) -> bool {
        self.mode == Mode::Team && (a % 2) == (b % 2)
    }

    fn generate(&mut self) {
        // deterministic regen per episode: advance the seed stream
        let mut rng = Pcg32::from_label(
            self.seed.wrapping_add(self.steps as u64),
            "pommerman-board",
        );
        self.board.fill(Cell::Passage);
        self.items.fill(None);
        self.hidden.fill(None);
        self.bombs.clear();
        self.flames.fill(0);

        // corner spawns (classic layout)
        let corners = [(1, 1), (SIZE as i32 - 2, 1), (SIZE as i32 - 2, SIZE as i32 - 2), (1, SIZE as i32 - 2)];
        // order: agent 0 TL, 1 TR, 2 BR, 3 BL so teams (0,2)/(1,3) are diagonal
        for (i, &c) in corners.iter().enumerate() {
            self.agents[i] = AgentState {
                pos: c,
                ammo: DEFAULT_AMMO,
                blast: DEFAULT_BLAST,
                can_kick: false,
                alive: true,
            };
        }

        // symmetric walls: draw in one half, mirror across the diagonal
        for y in 0..SIZE as i32 {
            for x in 0..=y {
                let r = rng.next_f32();
                let cell = if r < 0.18 {
                    Cell::Rigid
                } else if r < 0.45 {
                    Cell::Wood
                } else {
                    Cell::Passage
                };
                self.board[idx(x, y)] = cell;
                self.board[idx(y, x)] = cell;
            }
        }
        // carve the spawn pockets: corner + 2 cells along each edge
        for &(cx, cy) in &corners {
            for (dx, dy) in [(0, 0), (1, 0), (2, 0), (-1, 0), (-2, 0),
                             (0, 1), (0, 2), (0, -1), (0, -2)] {
                let (x, y) = (cx + dx, cy + dy);
                if in_bounds(x, y) {
                    self.board[idx(x, y)] = Cell::Passage;
                }
            }
        }
        // hide items under ~half the wood
        for i in 0..SIZE * SIZE {
            if self.board[i] == Cell::Wood && rng.chance(0.5) {
                self.hidden[i] = Some(match rng.below(3) {
                    0 => ItemKind::ExtraBomb,
                    1 => ItemKind::IncrRange,
                    _ => ItemKind::Kick,
                });
            }
        }
    }

    pub fn bomb_at(&self, x: i32, y: i32) -> Option<usize> {
        self.bombs.iter().position(|b| b.pos == (x, y))
    }

    pub fn agent_at(&self, x: i32, y: i32) -> Option<usize> {
        self.agents
            .iter()
            .position(|a| a.alive && a.pos == (x, y))
    }

    pub fn passable(&self, x: i32, y: i32) -> bool {
        in_bounds(x, y)
            && self.board[idx(x, y)] == Cell::Passage
            && self.bomb_at(x, y).is_none()
    }

    /// Per-cell "steps until a blast covers this cell" (i32::MAX = safe).
    /// Used by both the obs encoder (danger channel) and scripted agents.
    pub fn danger_map(&self) -> Vec<i32> {
        let mut danger = vec![i32::MAX; SIZE * SIZE];
        // iterate to fixpoint for chains: a bomb caught in another blast
        // fires at the earlier time
        let mut fire_at: Vec<i32> = self.bombs.iter().map(|b| b.timer).collect();
        loop {
            let mut changed = false;
            for (bi, b) in self.bombs.iter().enumerate() {
                let t = fire_at[bi];
                for (dx, dy) in [(0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)] {
                    for r in 0..=b.blast {
                        if r == 0 && (dx, dy) != (0, 0) {
                            continue;
                        }
                        let (x, y) = (b.pos.0 + dx * r, b.pos.1 + dy * r);
                        if !in_bounds(x, y) {
                            break;
                        }
                        let cell = self.board[idx(x, y)];
                        if cell == Cell::Rigid {
                            break;
                        }
                        if danger[idx(x, y)] > t {
                            danger[idx(x, y)] = t;
                            changed = true;
                        }
                        if let Some(oi) = self.bomb_at(x, y) {
                            if fire_at[oi] > t {
                                fire_at[oi] = t;
                                changed = true;
                            }
                        }
                        if cell == Cell::Wood {
                            break;
                        }
                        if (dx, dy) == (0, 0) {
                            break;
                        }
                    }
                }
            }
            if !changed {
                break;
            }
        }
        danger
    }

    fn explode(&mut self, rewards: &mut [f32; 4]) {
        // collect bombs due now, with chain propagation
        let mut due: Vec<usize> = self
            .bombs
            .iter()
            .enumerate()
            .filter(|(_, b)| b.timer <= 0)
            .map(|(i, _)| i)
            .collect();
        if due.is_empty() {
            return;
        }
        let mut exploded = vec![false; self.bombs.len()];
        let mut blast_cells: Vec<(usize, usize)> = Vec::new(); // (cell, owner)
        while let Some(bi) = due.pop() {
            if exploded[bi] {
                continue;
            }
            exploded[bi] = true;
            let b = self.bombs[bi];
            for (dx, dy) in [(0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)] {
                for r in 0..=b.blast {
                    if r == 0 && (dx, dy) != (0, 0) {
                        continue;
                    }
                    let (x, y) = (b.pos.0 + dx * r, b.pos.1 + dy * r);
                    if !in_bounds(x, y) {
                        break;
                    }
                    let cell = self.board[idx(x, y)];
                    if cell == Cell::Rigid {
                        break;
                    }
                    blast_cells.push((idx(x, y), b.owner));
                    if let Some(oi) = self.bomb_at(x, y) {
                        if !exploded[oi] {
                            due.push(oi); // chain
                        }
                    }
                    if cell == Cell::Wood {
                        break;
                    }
                    if (dx, dy) == (0, 0) {
                        break;
                    }
                }
            }
        }
        // apply blasts
        for &(ci, owner) in &blast_cells {
            self.flames[ci] = FLAME_LIFE;
            if self.board[ci] == Cell::Wood {
                self.board[ci] = Cell::Passage;
                if let Some(item) = self.hidden[ci].take() {
                    self.items[ci] = Some(item);
                }
                if self.shaping {
                    rewards[owner] += 0.02;
                }
            }
        }
        // refund ammo + drop exploded bombs
        let mut kept = Vec::with_capacity(self.bombs.len());
        for (i, b) in self.bombs.drain(..).enumerate() {
            if exploded[i] {
                self.agents[b.owner].ammo += 1;
            } else {
                kept.push(b);
            }
        }
        self.bombs = kept;
    }

    fn kill_agents_on_flames(&mut self, rewards: &mut [f32; 4]) {
        for i in 0..4 {
            if !self.agents[i].alive {
                continue;
            }
            let (x, y) = self.agents[i].pos;
            if self.flames[idx(x, y)] > 0 {
                self.agents[i].alive = false;
                if self.shaping {
                    rewards[i] -= 0.5;
                    // credit enemies (not precise attribution; cheap proxy)
                    for j in 0..4 {
                        if j != i && !self.same_team(i, j) && self.agents[j].alive {
                            rewards[j] += 0.2;
                        }
                    }
                }
            }
        }
    }

    fn team_alive(&self, team: usize) -> bool {
        (0..4).any(|i| i % 2 == team && self.agents[i].alive)
    }

    fn episode_result(&self) -> Option<Vec<f32>> {
        let t0 = self.team_alive(0);
        let t1 = self.team_alive(1);
        match self.mode {
            Mode::Team => {
                if t0 && t1 && self.steps < MAX_STEPS {
                    None
                } else if t0 && !t1 {
                    Some(vec![1.0, 0.0, 1.0, 0.0])
                } else if t1 && !t0 {
                    Some(vec![0.0, 1.0, 0.0, 1.0])
                } else {
                    Some(vec![0.5; 4])
                }
            }
            Mode::Ffa => {
                let alive: Vec<usize> =
                    (0..4).filter(|&i| self.agents[i].alive).collect();
                if alive.len() > 1 && self.steps < MAX_STEPS {
                    None
                } else if alive.len() == 1 {
                    let mut out = vec![0.0; 4];
                    out[alive[0]] = 1.0;
                    Some(out)
                } else {
                    // timeout or simultaneous death: survivors tie
                    let mut out = vec![0.0; 4];
                    for &i in &alive {
                        out[i] = 0.5;
                    }
                    if alive.is_empty() {
                        out = vec![0.25; 4];
                    }
                    Some(out)
                }
            }
        }
    }

    /// 9x9x12 egocentric view + 8 attributes for agent `who`.
    pub fn encode_obs(&self, who: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; OBS_DIM];
        let me = &self.agents[who];
        let (cx, cy) = me.pos;
        let half = VIEW as i32 / 2;
        let danger = self.danger_map();
        let ch = |c: usize, vx: usize, vy: usize| c * VIEW * VIEW + vy * VIEW + vx;
        for vy in 0..VIEW {
            for vx in 0..VIEW {
                let x = cx - half + vx as i32;
                let y = cy - half + vy as i32;
                if !in_bounds(x, y) {
                    out[ch(10, vx, vy)] = 1.0; // out-of-bounds
                    continue;
                }
                let i = idx(x, y);
                match self.board[i] {
                    Cell::Passage => out[ch(0, vx, vy)] = 1.0,
                    Cell::Rigid => out[ch(1, vx, vy)] = 1.0,
                    Cell::Wood => out[ch(2, vx, vy)] = 1.0,
                }
                if let Some(bi) = self.bomb_at(x, y) {
                    let b = &self.bombs[bi];
                    out[ch(3, vx, vy)] = b.timer as f32 / BOMB_LIFE as f32;
                    out[ch(9, vx, vy)] = b.blast as f32 / 5.0;
                }
                if self.flames[i] > 0 {
                    out[ch(4, vx, vy)] = 1.0;
                }
                if self.items[i].is_some() {
                    out[ch(5, vx, vy)] = 1.0;
                }
                if let Some(a) = self.agent_at(x, y) {
                    if a == who {
                        out[ch(6, vx, vy)] = 1.0;
                    } else if self.same_team(who, a) {
                        out[ch(7, vx, vy)] = 1.0;
                    } else {
                        out[ch(8, vx, vy)] = 1.0;
                    }
                }
                if danger[i] != i32::MAX {
                    out[ch(11, vx, vy)] =
                        1.0 - (danger[i] as f32 / BOMB_LIFE as f32).min(1.0);
                }
            }
        }
        let base = VIEW * VIEW * 12;
        out[base] = me.ammo as f32 / 3.0;
        out[base + 1] = me.blast as f32 / 5.0;
        out[base + 2] = me.can_kick as u8 as f32;
        out[base + 3] = me.alive as u8 as f32;
        let mate = Self::teammate(who);
        out[base + 4] = if self.mode == Mode::Team {
            self.agents[mate].alive as u8 as f32
        } else {
            0.0
        };
        let enemies_alive = (0..4)
            .filter(|&i| i != who && !self.same_team(who, i) && self.agents[i].alive)
            .count();
        out[base + 5] = enemies_alive as f32 / 3.0;
        out[base + 6] = self.steps as f32 / MAX_STEPS as f32;
        out[base + 7] = if self.mode == Mode::Team { 1.0 } else { 0.0 };
        out
    }

    fn all_obs(&self) -> Vec<Vec<f32>> {
        (0..4).map(|i| self.encode_obs(i)).collect()
    }
}

impl MultiAgentEnv for Pommerman {
    fn n_agents(&self) -> usize {
        4
    }
    fn obs_dim(&self) -> usize {
        OBS_DIM
    }
    fn act_dim(&self) -> usize {
        6
    }
    fn max_steps(&self) -> usize {
        MAX_STEPS
    }

    fn reset(&mut self) -> Vec<Vec<f32>> {
        // fresh board each episode; seed advanced so layouts differ
        self.seed = self.seed.wrapping_add(0x9e37_79b9);
        self.steps = 0;
        self.done = false;
        self.generate();
        self.all_obs()
    }

    fn step(&mut self, actions: &[usize]) -> Step {
        assert!(!self.done, "step after done");
        assert_eq!(actions.len(), 4);
        self.steps += 1;
        let mut rewards = [0.0f32; 4];

        // 1. flames decay
        for f in self.flames.iter_mut() {
            if *f > 0 {
                *f -= 1;
            }
        }

        // 2. bomb placement (before movement, classic rules)
        for i in 0..4 {
            let a = &mut self.agents[i];
            if a.alive
                && actions[i] == ACT_BOMB
                && a.ammo > 0
                && self.bombs.iter().all(|b| b.pos != a.pos)
            {
                let blast = a.blast;
                let pos = a.pos;
                a.ammo -= 1;
                self.bombs.push(Bomb {
                    pos,
                    owner: i,
                    timer: BOMB_LIFE,
                    blast,
                    vel: (0, 0),
                });
            }
        }

        // 3. agent movement with collision resolution
        let mut desired: Vec<(i32, i32)> = (0..4)
            .map(|i| {
                let a = &self.agents[i];
                if !a.alive || actions[i] == ACT_BOMB || actions[i] == ACT_IDLE {
                    return a.pos;
                }
                let (dx, dy) = action_delta(actions[i]);
                let (nx, ny) = (a.pos.0 + dx, a.pos.1 + dy);
                if !in_bounds(nx, ny) || self.board[idx(nx, ny)] != Cell::Passage {
                    return a.pos;
                }
                if let Some(bi) = self.bomb_at(nx, ny) {
                    // kick if empowered and space behind the bomb is free
                    if a.can_kick {
                        let (bx, by) = (nx + dx, ny + dy);
                        if self.passable(bx, by) && self.agent_at(bx, by).is_none() {
                            self.bombs[bi].vel = (dx, dy);
                            return (nx, ny);
                        }
                    }
                    let _ = bi;
                    return a.pos;
                }
                (nx, ny)
            })
            .collect();
        // two agents to the same cell: both bounce
        loop {
            let mut conflicted = false;
            for i in 0..4 {
                for j in (i + 1)..4 {
                    if self.agents[i].alive
                        && self.agents[j].alive
                        && desired[i] == desired[j]
                    {
                        desired[i] = self.agents[i].pos;
                        desired[j] = self.agents[j].pos;
                        conflicted = true;
                    }
                }
            }
            // swap-through is also forbidden
            for i in 0..4 {
                for j in (i + 1)..4 {
                    if self.agents[i].alive
                        && self.agents[j].alive
                        && desired[i] == self.agents[j].pos
                        && desired[j] == self.agents[i].pos
                    {
                        desired[i] = self.agents[i].pos;
                        desired[j] = self.agents[j].pos;
                        conflicted = true;
                    }
                }
            }
            if !conflicted {
                break;
            }
        }
        for i in 0..4 {
            if !self.agents[i].alive {
                continue;
            }
            self.agents[i].pos = desired[i];
            // item pickup
            let (x, y) = desired[i];
            if let Some(item) = self.items[idx(x, y)].take() {
                match item {
                    ItemKind::ExtraBomb => self.agents[i].ammo += 1,
                    ItemKind::IncrRange => self.agents[i].blast += 1,
                    ItemKind::Kick => self.agents[i].can_kick = true,
                }
                if self.shaping {
                    rewards[i] += 0.05;
                }
            }
        }

        // 4. kicked bombs slide
        for bi in 0..self.bombs.len() {
            let b = self.bombs[bi];
            if b.vel == (0, 0) {
                continue;
            }
            let (nx, ny) = (b.pos.0 + b.vel.0, b.pos.1 + b.vel.1);
            if in_bounds(nx, ny)
                && self.board[idx(nx, ny)] == Cell::Passage
                && self.agent_at(nx, ny).is_none()
                && self
                    .bombs
                    .iter()
                    .enumerate()
                    .all(|(oi, o)| oi == bi || o.pos != (nx, ny))
            {
                self.bombs[bi].pos = (nx, ny);
            } else {
                self.bombs[bi].vel = (0, 0);
            }
        }

        // 5. timers + explosions + deaths
        for b in self.bombs.iter_mut() {
            b.timer -= 1;
        }
        self.explode(&mut rewards);
        self.kill_agents_on_flames(&mut rewards);

        // 6. outcome
        let result = self.episode_result();
        let done = result.is_some();
        self.done = done;
        let mut rew = rewards.to_vec();
        if let Some(out) = &result {
            for i in 0..4 {
                rew[i] += out[i] * 2.0 - 1.0; // +1 win, 0 tie, -1 loss
            }
        }
        Step {
            obs: self.all_obs(),
            rewards: rew,
            done,
            info: Info { outcome: result, frags: None },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh(seed: u64) -> Pommerman {
        let mut env = Pommerman::team(seed);
        env.reset();
        env
    }

    #[test]
    fn board_has_free_spawns() {
        for seed in 0..20 {
            let env = fresh(seed);
            for a in &env.agents {
                assert_eq!(env.board[idx(a.pos.0, a.pos.1)], Cell::Passage);
                // at least one free neighbour
                let free = [(1, 0), (-1, 0), (0, 1), (0, -1)]
                    .iter()
                    .filter(|(dx, dy)| env.passable(a.pos.0 + dx, a.pos.1 + dy))
                    .count();
                assert!(free >= 1, "seed {seed}");
            }
        }
    }

    #[test]
    fn bomb_explodes_after_life_and_refunds_ammo() {
        let mut env = fresh(1);
        let a0 = env.agents[0].pos;
        let idle = [ACT_IDLE; 4];
        let mut acts = idle;
        acts[0] = ACT_BOMB;
        env.step(&acts.to_vec());
        assert_eq!(env.bombs.len(), 1);
        assert_eq!(env.agents[0].ammo, 0);
        // walk agent 0 away so it survives: move right/down repeatedly
        for t in 0..BOMB_LIFE {
            let mut acts = idle;
            acts[0] = if t % 2 == 0 { ACT_RIGHT } else { ACT_DOWN };
            if env.done {
                break;
            }
            env.step(&acts.to_vec());
        }
        assert!(env.bombs.is_empty(), "bomb must have exploded");
        assert_eq!(env.agents[0].ammo, 1, "ammo refunded");
        let _ = a0;
    }

    #[test]
    fn flame_kills_idle_owner() {
        let mut env = fresh(2);
        env.shaping = false;
        let idle = [ACT_IDLE; 4];
        let mut acts = idle;
        acts[0] = ACT_BOMB;
        env.step(&acts.to_vec());
        for _ in 0..BOMB_LIFE {
            if env.done {
                break;
            }
            env.step(&idle.to_vec());
        }
        assert!(!env.agents[0].alive, "idle bomber must die in own blast");
    }

    #[test]
    fn rigid_blocks_blast() {
        let mut env = fresh(3);
        // construct a controlled scene
        env.board.fill(Cell::Passage);
        env.board[idx(5, 4)] = Cell::Rigid;
        env.bombs.clear();
        env.bombs.push(Bomb {
            pos: (5, 5),
            owner: 0,
            timer: 1,
            blast: 3,
            vel: (0, 0),
        });
        env.agents[0].pos = (0, 0);
        env.agents[1].pos = (10, 10);
        env.agents[2].pos = (0, 10);
        env.agents[3].pos = (10, 0);
        let mut rewards = [0.0; 4];
        for b in env.bombs.iter_mut() {
            b.timer -= 1;
        }
        env.explode(&mut rewards);
        assert!(env.flames[idx(5, 5)] > 0);
        assert!(env.flames[idx(4, 5)] > 0);
        assert_eq!(env.flames[idx(5, 3)], 0, "rigid wall blocks flame");
        assert_eq!(env.flames[idx(5, 4)], 0, "rigid cell itself unburnt");
    }

    #[test]
    fn wood_stops_blast_and_reveals_item() {
        let mut env = fresh(4);
        env.board.fill(Cell::Passage);
        env.board[idx(5, 3)] = Cell::Wood;
        env.hidden[idx(5, 3)] = Some(ItemKind::Kick);
        env.bombs.clear();
        env.bombs.push(Bomb {
            pos: (5, 5),
            owner: 0,
            timer: 0,
            blast: 4,
            vel: (0, 0),
        });
        env.agents[0].pos = (0, 0);
        env.agents[1].pos = (10, 10);
        env.agents[2].pos = (0, 10);
        env.agents[3].pos = (10, 0);
        let mut rewards = [0.0; 4];
        env.explode(&mut rewards);
        assert_eq!(env.board[idx(5, 3)], Cell::Passage, "wood destroyed");
        assert_eq!(env.items[idx(5, 3)], Some(ItemKind::Kick));
        assert_eq!(env.flames[idx(5, 2)], 0, "blast stops at wood");
    }

    #[test]
    fn chain_explosions() {
        let mut env = fresh(5);
        env.board.fill(Cell::Passage);
        env.bombs.clear();
        env.bombs.push(Bomb { pos: (5, 5), owner: 0, timer: 0, blast: 2, vel: (0, 0) });
        env.bombs.push(Bomb { pos: (7, 5), owner: 1, timer: 9, blast: 2, vel: (0, 0) });
        env.agents[0].pos = (0, 0);
        env.agents[1].pos = (10, 10);
        env.agents[2].pos = (0, 10);
        env.agents[3].pos = (10, 0);
        let mut rewards = [0.0; 4];
        env.explode(&mut rewards);
        assert!(env.bombs.is_empty(), "chained bomb must also explode");
        assert!(env.flames[idx(9, 5)] > 0, "chained blast extends");
    }

    #[test]
    fn team_outcome_when_opponents_die() {
        let mut env = fresh(6);
        env.agents[1].alive = false;
        env.agents[3].alive = false;
        let s = env.step(&vec![ACT_IDLE; 4]);
        assert!(s.done);
        assert_eq!(s.info.outcome.unwrap(), vec![1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn tie_at_step_cap() {
        let mut env = fresh(7);
        env.steps = MAX_STEPS - 1;
        let s = env.step(&vec![ACT_IDLE; 4]);
        assert!(s.done);
        assert_eq!(s.info.outcome.unwrap(), vec![0.5; 4]);
    }

    #[test]
    fn obs_dim_matches_manifest_spec() {
        let env = fresh(8);
        assert_eq!(env.encode_obs(0).len(), OBS_DIM);
        assert_eq!(OBS_DIM, 9 * 9 * 12 + 8);
    }

    #[test]
    fn obs_self_channel_is_centered() {
        let env = fresh(9);
        let obs = env.encode_obs(2);
        let center = 6 * VIEW * VIEW + (VIEW / 2) * VIEW + VIEW / 2;
        assert_eq!(obs[center], 1.0, "self channel must mark the center");
    }

    #[test]
    fn danger_map_marks_blast_cross() {
        let mut env = fresh(10);
        env.board.fill(Cell::Passage);
        env.bombs.clear();
        env.bombs.push(Bomb { pos: (5, 5), owner: 0, timer: 4, blast: 2, vel: (0, 0) });
        let d = env.danger_map();
        assert_eq!(d[idx(5, 5)], 4);
        assert_eq!(d[idx(7, 5)], 4);
        assert_eq!(d[idx(5, 7)], 4);
        assert_eq!(d[idx(8, 5)], i32::MAX, "outside blast radius");
        assert_eq!(d[idx(6, 6)], i32::MAX, "diagonal is safe");
    }

    #[test]
    fn movement_collision_bounces_both() {
        let mut env = fresh(11);
        env.board.fill(Cell::Passage);
        env.bombs.clear();
        env.agents[0].pos = (4, 5);
        env.agents[1].pos = (6, 5);
        env.agents[2].pos = (0, 0);
        env.agents[3].pos = (10, 10);
        let mut acts = vec![ACT_IDLE; 4];
        acts[0] = ACT_RIGHT;
        acts[1] = ACT_LEFT;
        env.step(&acts);
        assert_eq!(env.agents[0].pos, (4, 5));
        assert_eq!(env.agents[1].pos, (6, 5));
    }
}
