//! Role worker: the body of `tleague worker --role <r> --controller
//! <addr>` — registers with the controller, runs exactly one role, and
//! heartbeats until told to stop.
//!
//! Life cycle (see DESIGN.md §Process deployment):
//!
//!   register → Assign → (WorkerReady) → run role + heartbeat
//!     ├─ heartbeat ack `stop=true`  → deregister, exit 0
//!     ├─ role error (stale endpoints, peer died) → deregister,
//!     │    re-register with the old slot as a hint, restart the role
//!     │    with fresh addresses — the cross-process analogue of the
//!     │    thread supervisor's restart loop
//!     └─ heartbeat says "unknown worker" (controller restarted) →
//!          re-register; the role restarts against the resumed services
//!          (learners refetch params from the pool, actors new tasks)

use crate::actor::ActorConfig;
use crate::inference::{InfServer, InfServerConfig};
use crate::learner::allreduce::Allreduce;
use crate::learner::replay::ReplayMode;
use crate::learner::LearnerConfig;
use crate::orchestrator::{learner_thread, run_actor, LearnerStatus};
use crate::proto::{Msg, RoleStats, WorkerAssignment};
use crate::runtime::Engine;
use crate::telemetry::{snapshot_role, trace};
use crate::transport::{fault, ReqClient};
use crate::util::metrics::MetricsHub;
use crate::util::rng::Pcg32;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// State shared between the role loop and the heartbeat thread.
#[derive(Default)]
struct HbShared {
    steps: AtomicU64,
    done: AtomicBool,
    /// controller acked stop: wind down cleanly
    stop: AtomicBool,
    /// registration no longer valid (controller restarted / we were
    /// declared dead): re-register
    lost: AtomicBool,
    /// role loop over: heartbeat thread exits
    finished: AtomicBool,
}

impl HbShared {
    fn should_stop(&self, proc_stop: &AtomicBool) -> bool {
        proc_stop.load(Ordering::Relaxed)
            || self.stop.load(Ordering::Relaxed)
            || self.lost.load(Ordering::Relaxed)
    }
}

/// A drained-but-unconfirmed telemetry snapshot.  Lives beside the hub
/// for the whole worker process, so a snapshot parked by a dying
/// heartbeat thread is retried VERBATIM (same seq) on the next
/// registration's first beat: if the original delivery actually reached
/// the controller (reply lost), the seq dedupe drops the retry instead
/// of double-counting the deltas.
type PendingSnap = Arc<std::sync::Mutex<Option<RoleStats>>>;

#[allow(clippy::too_many_arguments)]
fn spawn_heartbeat(
    addr: String,
    worker_id: u64,
    every_ms: u64,
    hb: Arc<HbShared>,
    hub: Arc<MetricsHub>,
    pending: PendingSnap,
    stats_seq: Arc<AtomicU64>,
    role: String,
    slot: u32,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("heartbeat-{worker_id}"))
        .spawn(move || {
            let client = ReqClient::connect(&addr);
            let every = Duration::from_millis(every_ms.max(10));
            let mut finishing = false;
            loop {
                // sleep in small slices so `finished` is honored fast
                let t0 = Instant::now();
                while t0.elapsed() < every && !finishing {
                    if hb.finished.load(Ordering::Relaxed) {
                        // role loop over: flush ONE final beat so the
                        // last partial interval's deltas reach the
                        // controller's run totals, then exit
                        finishing = true;
                    } else {
                        std::thread::sleep(Duration::from_millis(
                            every_ms.clamp(1, 25),
                        ));
                    }
                }
                // retry an undelivered snapshot verbatim first (the hub
                // keeps accumulating and is drained next beat), else
                // drain this interval's deltas under a fresh seq; an
                // empty hub (role still starting) sends nothing
                let (snap, was_pending) = {
                    let mut p = pending.lock().unwrap();
                    match p.take() {
                        Some(s) => (s, true),
                        None => {
                            let mut s = snapshot_role(&hub, &role, slot);
                            // piggyback the flight recorder's recent
                            // spans (bounded; the ring keeps refilling)
                            s.spans = trace::recorder().drain(512);
                            s.seq = stats_seq
                                .fetch_add(1, Ordering::Relaxed)
                                + 1;
                            (s, false)
                        }
                    }
                };
                let has_stats = !snap.counters.is_empty()
                    || !snap.gauges.is_empty()
                    || !snap.hists.is_empty()
                    || !snap.spans.is_empty();
                let msg = Msg::Heartbeat {
                    worker_id,
                    steps: hb.steps.load(Ordering::Relaxed),
                    done: hb.done.load(Ordering::Relaxed),
                    stats: has_stats.then(|| snap.clone()),
                };
                match client.request(&msg) {
                    Ok(Msg::HeartbeatAck { stop }) => {
                        if stop {
                            hb.stop.store(true, Ordering::Relaxed);
                        }
                    }
                    Ok(_) | Err(_) => {
                        // drained but unconfirmed: park the snapshot —
                        // run totals must not lose events, and the
                        // retained seq lets the controller drop the
                        // retry if this delivery actually landed
                        if has_stats {
                            *pending.lock().unwrap() = Some(snap);
                        }
                        // unknown-worker or controller unreachable:
                        // the role loop re-registers
                        hb.lost.store(true, Ordering::Relaxed);
                        break;
                    }
                }
                if finishing {
                    if was_pending {
                        // the final beat's slot went to the retried
                        // snapshot; loop once more (no sleep — the
                        // slice loop short-circuits on `finishing`) to
                        // flush the fresh tail interval as well
                        continue;
                    }
                    break;
                }
            }
        })
        .expect("spawn heartbeat")
}

/// Register with the controller, honoring `Retry` backoff, until an
/// assignment arrives, the controller says the run is over
/// (`Msg::Shutdown` → clean exit), or `proc_stop`.  Transport errors
/// are retried a bounded number of times — a vanished controller must
/// not leave immortal workers spinning (each `request` already spends
/// ~10s of internal reconnect attempts).
fn register(
    client: &ReqClient,
    role: &str,
    slot_hint: i64,
    proc_stop: &AtomicBool,
) -> Result<Option<WorkerAssignment>> {
    let mut last_reason = String::new();
    let mut unreachable = 0u32;
    // per-process jitter stream: after a controller restart every
    // surviving worker re-registers at once, and un-jittered backoff
    // would keep that thundering herd marching in lockstep forever
    let mut jitter =
        Pcg32::from_label(u64::from(std::process::id()), "register-jitter");
    loop {
        if proc_stop.load(Ordering::Relaxed) {
            return Ok(None);
        }
        match client.request(&Msg::Register { role: role.into(), slot_hint }) {
            Ok(Msg::Assign(a)) => return Ok(Some(a)),
            Ok(Msg::Shutdown) => {
                eprintln!("worker({role}): run is draining; exiting");
                return Ok(None);
            }
            Ok(Msg::Retry { backoff_ms, reason }) => {
                unreachable = 0;
                if reason != last_reason {
                    eprintln!("worker({role}): waiting — {reason}");
                    last_reason = reason;
                }
                // spread sleeps over [base/2, 3*base/2]
                let base = u64::from(backoff_ms).clamp(10, 10_000);
                let spread = base / 2 + u64::from(jitter.below(base as u32 + 1));
                std::thread::sleep(Duration::from_millis(spread));
            }
            Ok(Msg::Err(e)) => bail!("register rejected: {e}"),
            Ok(other) => bail!("register: unexpected reply {other:?}"),
            Err(_) => {
                unreachable += 1;
                if unreachable >= 20 {
                    bail!("controller unreachable after {unreachable} attempts");
                }
                eprintln!("worker({role}): controller unreachable, retrying");
                std::thread::sleep(Duration::from_millis(
                    250 + u64::from(jitter.below(501)),
                ));
            }
        }
    }
}

/// Endpoint options for one worker: where role services bind, and what
/// host peers should be told to reach them at (`advertise_host` is
/// required in practice when binding 0.0.0.0 — see
/// [`super::advertised`]).
#[derive(Clone, Default)]
pub struct WorkerNet {
    pub bind_host: String,
    pub advertise_host: Option<String>,
}

impl WorkerNet {
    fn advertised(&self, addr: &str) -> String {
        super::advertised(addr, self.advertise_host.as_deref())
    }
}

/// Run one role worker until the controller stops it (Ok) or the
/// process is signalled.  Re-registers and restarts the role on
/// failures and controller restarts.
pub fn run_worker(
    role: &str,
    controller_addr: &str,
    engine: Arc<Engine>,
    net: &WorkerNet,
    proc_stop: &AtomicBool,
) -> Result<()> {
    let client = ReqClient::connect(controller_addr);
    let mut slot_hint: i64 = -1;
    let mut consecutive_failures = 0u32;
    // ONE telemetry hub (+ undelivered-snapshot buffer + seq counter)
    // for the worker's lifetime: the role registers its meters here,
    // the heartbeat thread snapshots them, and a snapshot parked after
    // a failed delivery survives re-registration.  Seeding the seq
    // stream from the pid keeps it unique across worker processes that
    // take over the same slot, so the controller's per-slot dedupe
    // never mistakes a fresh worker's snapshot for a retransmit.
    let hub = Arc::new(MetricsHub::default());
    // fault-plan counters ride this worker's snapshots so the league
    // telemetry report shows injections/recoveries per role
    hub.register("faults_injected", fault::injected_meter());
    hub.register("recoveries", fault::recovered_meter());
    let pending: PendingSnap = Default::default();
    let stats_seq =
        Arc::new(AtomicU64::new((std::process::id() as u64) << 32));
    loop {
        let Some(asn) = register(&client, role, slot_hint, proc_stop)? else {
            return Ok(()); // signalled while waiting, or run already draining
        };
        slot_hint = asn.slot as i64;
        eprintln!(
            "worker({role}): assigned slot {} as worker {}",
            asn.slot, asn.worker_id
        );
        // run-wide tracing knobs arrive with the assignment
        trace::set_slow_ms(asn.run.trace_slow_ms);
        // ... as does the pool replication factor: every ModelPoolClient
        // this role builds derives the same shard placement the
        // controller's replicas enforce
        crate::model_pool::set_default_replication(
            asn.run.pool_replication as usize,
        );
        // ... and so does the fault plan: every process compiles the
        // same seeded plan, scoped here to this worker's role
        fault::set_role(role);
        if asn.run.fault_spec.is_empty() {
            fault::clear();
        } else if let Err(e) =
            fault::install_spec(asn.run.fault_seed, &asn.run.fault_spec)
        {
            // the controller validated the spec; a parse failure here
            // means version skew — run un-faulted rather than die
            eprintln!("worker({role}): ignoring fault spec: {e:#}");
        }
        let hb = Arc::new(HbShared::default());
        let hb_handle = spawn_heartbeat(
            controller_addr.to_string(),
            asn.worker_id,
            asn.run.heartbeat_ms,
            hb.clone(),
            hub.clone(),
            pending.clone(),
            stats_seq.clone(),
            asn.role.clone(),
            asn.slot,
        );
        let role_started = Instant::now();
        let res =
            run_role(&asn, engine.clone(), net, proc_stop, &hb, &client, &hub);
        hb.finished.store(true, Ordering::Relaxed);
        hb_handle.join().ok();
        // best-effort goodbye; on a lost registration the id is stale
        // and the controller answers Err, which is fine
        let _ = client.request(&Msg::Deregister { worker_id: asn.worker_id });
        let told_to_stop =
            proc_stop.load(Ordering::Relaxed) || hb.stop.load(Ordering::Relaxed);
        match res {
            Ok(()) if told_to_stop => {
                eprintln!("worker({role}): clean stop");
                return Ok(());
            }
            Ok(()) => {
                // registration lost (controller restart): re-register
                consecutive_failures = 0;
            }
            Err(e) => {
                if told_to_stop {
                    return Ok(()); // failures during shutdown are expected
                }
                // only *consecutive* fast failures count: a role that ran
                // healthily for a while before failing (peer restarted
                // hours in) starts a fresh streak, so a long-lived worker
                // never accumulates its way into giving up
                if role_started.elapsed() >= Duration::from_secs(60) {
                    consecutive_failures = 0;
                }
                consecutive_failures += 1;
                if consecutive_failures >= 10 {
                    return Err(e.context("worker: giving up after 10 failures"));
                }
                eprintln!("worker({role}): role failed ({e:#}); re-registering");
                std::thread::sleep(Duration::from_millis(200));
            }
        }
    }
}

fn run_role(
    asn: &WorkerAssignment,
    engine: Arc<Engine>,
    net: &WorkerNet,
    proc_stop: &AtomicBool,
    hb: &Arc<HbShared>,
    ctrl: &ReqClient,
    hub: &Arc<MetricsHub>,
) -> Result<()> {
    match asn.role.as_str() {
        super::controller::ROLE_LEARNER => {
            run_learner_role(asn, engine, net, proc_stop, hb, ctrl, hub)
        }
        super::controller::ROLE_ACTOR => {
            run_actor_role(asn, engine, proc_stop, hb, hub)
        }
        super::controller::ROLE_INF => {
            run_inf_role(asn, engine, net, proc_stop, hb, ctrl, hub)
        }
        other => bail!("unknown role '{other}' in assignment"),
    }
}

fn report_ready(ctrl: &ReqClient, worker_id: u64, addrs: Vec<String>) -> Result<()> {
    match ctrl.request(&Msg::WorkerReady { worker_id, addrs })? {
        Msg::Ok => Ok(()),
        other => bail!("WorkerReady: unexpected reply {other:?}"),
    }
}

/// A learner worker hosts its agent's WHOLE allreduce group as threads
/// (gradient reduction is intra-process), reporting one data port per
/// rank.  After training completes it keeps the data ports open — and
/// heartbeats `done` — until the controller acks stop.
#[allow(clippy::too_many_arguments)]
fn run_learner_role(
    asn: &WorkerAssignment,
    engine: Arc<Engine>,
    net: &WorkerNet,
    proc_stop: &AtomicBool,
    hb: &Arc<HbShared>,
    ctrl: &ReqClient,
    hub: &Arc<MetricsHub>,
) -> Result<()> {
    let run = &asn.run;
    let n_ranks = (run.learners_per_agent as usize).max(1);
    let group = Allreduce::new(n_ranks);
    let manifest_env = crate::envs::manifest_name(&run.env).to_string();
    // strict: a version-skewed controller's slice must fail loudly
    let replay_mode = ReplayMode::parse(&run.replay_mode)?;
    let role_stop = Arc::new(AtomicBool::new(false));
    let mut statuses = Vec::new();
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for rank in 0..n_ranks {
        let status = Arc::new(LearnerStatus::default());
        statuses.push(status.clone());
        let (tx, rx) = std::sync::mpsc::channel::<String>();
        let lcfg = LearnerConfig {
            env: manifest_env.clone(),
            agent: asn.agent,
            rank,
            algo: run.algo.clone(),
            replay_mode,
            publish_every: run.publish_every,
            period_steps: run.period_steps,
            replay_cap: 8192,
            seed: run.seed + asn.agent as u64 * 100 + rank as u64,
            data_bind: format!("{}:0", net.bind_host),
        };
        let engine = engine.clone();
        let pool_addrs = asn.pool_addrs.clone();
        let league_addr = asn.league_addr.clone();
        let group = group.clone();
        let stop = role_stop.clone();
        let total = run.total_steps;
        // every rank shares the worker hub: the slot's snapshot carries
        // group-wide recv/consumed frame counters
        let hub2 = hub.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("learner-{}-{rank}", asn.agent))
                .spawn(move || -> Result<()> {
                    learner_thread(
                        lcfg,
                        engine,
                        pool_addrs,
                        league_addr,
                        Some(group),
                        status,
                        stop,
                        total,
                        tx,
                        Some(hub2),
                    )
                })?,
        );
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(addr) => addrs.push(net.advertised(&addr)),
            Err(_) => {
                // surface the thread's real startup error (league
                // unreachable, bind failure, ...), not just the symptom.
                // Poison the group so ranks blocked in reduce wake up
                // instead of deadlocking this join.
                role_stop.store(true, Ordering::Relaxed);
                group.poison();
                let mut cause = None;
                for h in handles.drain(..) {
                    if let Ok(Err(e)) = h.join() {
                        cause.get_or_insert(e);
                    }
                }
                return Err(match cause {
                    Some(e) => {
                        e.context(format!("learner rank {rank} died at startup"))
                    }
                    None => anyhow::anyhow!(
                        "learner rank {rank} never reported its data port"
                    ),
                });
            }
        }
    }
    if let Err(e) = report_ready(ctrl, asn.worker_id, addrs) {
        // never leave the group training unsupervised: a re-register
        // would spawn a second group against the same league
        role_stop.store(true, Ordering::Relaxed);
        group.poison();
        for h in handles {
            h.join().ok();
        }
        return Err(e);
    }

    // supervise: mirror progress into the heartbeat, catch dead threads
    let mut early_exit = false;
    loop {
        std::thread::sleep(Duration::from_millis(50));
        let steps: u64 = statuses.iter().map(|s| s.steps.load(Ordering::Relaxed)).sum();
        let done = statuses.iter().all(|s| s.done.load(Ordering::Relaxed));
        hb.steps.store(steps, Ordering::Relaxed);
        hb.done.store(done, Ordering::Relaxed);
        if hb.should_stop(proc_stop) {
            break;
        }
        // a learner thread that died before finishing = role failure
        early_exit = handles
            .iter()
            .zip(&statuses)
            .any(|(h, s)| h.is_finished() && !s.done.load(Ordering::Relaxed));
        if early_exit {
            break;
        }
    }
    role_stop.store(true, Ordering::Relaxed);
    // a rank blocked in reduce (peer already exited, or mid-run death —
    // the early_exit case) would hang this join forever without poison
    group.poison();
    let mut first_err = None;
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => {
                first_err =
                    first_err.or_else(|| Some(anyhow::anyhow!("learner panicked")))
            }
        }
    }
    match first_err {
        Some(e) if early_exit => Err(e.context("learner thread died mid-run")),
        _ => Ok(()),
    }
}

/// An actor worker drives one Actor.  Unlike the thread supervisor it
/// does NOT restart in place on failure: it returns the error so the
/// worker loop re-registers and restarts with fresh endpoints (its
/// learner may have moved).
fn run_actor_role(
    asn: &WorkerAssignment,
    engine: Arc<Engine>,
    proc_stop: &AtomicBool,
    hb: &Arc<HbShared>,
    hub: &Arc<MetricsHub>,
) -> Result<()> {
    let run = &asn.run;
    // slot-derived identity mirrors the thread-mode spawn order, so a
    // procs run samples the same actor RNG streams as a thread run
    let acfg = ActorConfig {
        env: run.env.clone(),
        actor_id: format!("{}/a{}", asn.agent, asn.slot),
        seed: run.seed * 1000 + asn.slot as u64,
        gamma: run.gamma,
        refresh_every: run.refresh_every,
        train_t: 0,
        trace_sample: run.trace_sample as f32,
    };
    let role_stop = Arc::new(AtomicBool::new(false));
    // lane policy rides the controller's RunSlice: every actor worker
    // colocated with its inference server picks the shm lane the same way
    let lanes =
        crate::transport::LaneOpts::from_config(&run.local_lanes, &run.shm_dir);
    let handle = {
        let asn = asn.clone();
        let engine = engine.clone();
        let stop = role_stop.clone();
        let hub = hub.clone();
        let envs_per_actor = (run.envs_per_actor as usize).max(1);
        std::thread::Builder::new()
            .name(format!("actor-{}", acfg.actor_id))
            .spawn(move || -> Result<()> {
                let inf = (!asn.inf_addr.is_empty()).then_some(asn.inf_addr.as_str());
                run_actor(
                    acfg,
                    envs_per_actor,
                    inf,
                    lanes,
                    &engine,
                    &asn.league_addr,
                    &asn.pool_addrs,
                    &asn.data_addr,
                    &stop,
                    Some(&hub),
                )
            })
            .expect("spawn actor")
    };
    while !hb.should_stop(proc_stop) && !handle.is_finished() {
        std::thread::sleep(Duration::from_millis(25));
    }
    let stopping = hb.should_stop(proc_stop);
    role_stop.store(true, Ordering::Relaxed);
    match handle.join() {
        Ok(Ok(())) => Ok(()),
        // failures during shutdown are expected (peers wind down too)
        Ok(Err(_)) if stopping => Ok(()),
        Ok(Err(e)) => Err(e),
        Err(_) if stopping => Ok(()),
        Err(_) => bail!("actor panicked"),
    }
}

#[allow(clippy::too_many_arguments)]
fn run_inf_role(
    asn: &WorkerAssignment,
    engine: Arc<Engine>,
    net: &WorkerNet,
    proc_stop: &AtomicBool,
    hb: &Arc<HbShared>,
    ctrl: &ReqClient,
    hub: &Arc<MetricsHub>,
) -> Result<()> {
    let run = &asn.run;
    let manifest_env = crate::envs::manifest_name(&run.env).to_string();
    let m = engine.manifest.env(&manifest_env)?;
    let mut inf = InfServer::start_with_hub(
        &format!("{}:0", net.bind_host),
        InfServerConfig {
            env: manifest_env.clone(),
            batch: m.infer_b,
            max_wait: Duration::from_micros(run.infer_max_wait_us),
            refresh: Duration::from_millis(run.infer_refresh_ms),
            net_threads: run.net_threads as usize,
        },
        engine.clone(),
        &asn.pool_addrs,
        hub.clone(),
    )?;
    report_ready(ctrl, asn.worker_id, vec![net.advertised(&inf.addr)])?;
    while !hb.should_stop(proc_stop) {
        std::thread::sleep(Duration::from_millis(50));
    }
    inf.shutdown();
    Ok(())
}
