//! Quickstart: the smallest full-stack TLeague run.
//!
//! Launches a complete league on Rock-Paper-Scissors — ModelPool,
//! LeagueMgr (uniform opponent sampling), one PPO Learner, two Actors —
//! trains for 60 learner steps, and prints the league state.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;
use std::time::Duration;
use tleague::config::RunConfig;
use tleague::orchestrator::Deployment;
use tleague::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Arc::new(Engine::load("artifacts")?);

    let mut cfg = RunConfig::default();
    cfg.env = "rps".into();
    cfg.game_mgr = "uniform".into();
    cfg.actors_per_learner = 2;
    cfg.total_steps = 60;
    cfg.period_steps = 15; // freeze a model into the pool every 15 steps
    cfg.publish_every = 3;

    println!("== TLeague quickstart: CSP-MARL on Rock-Paper-Scissors ==");
    let mut dep = Deployment::start(cfg, engine)?;
    while !dep.learners_done() {
        std::thread::sleep(Duration::from_millis(500));
        let stats = dep.league_stats();
        let ls = dep.learner_status[0].stats.lock().unwrap().clone();
        println!(
            "steps={:3}  pool={:2}  episodes={:5}  loss={:+.4}  entropy={:.3}",
            dep.total_learner_steps(),
            stats.pool_size,
            stats.episodes,
            ls.loss,
            ls.entropy
        );
    }
    let stats = dep.league_stats();
    println!("\nleague finished: {} frozen models, {} episodes, {} frames",
             stats.pool_size, stats.episodes, stats.frames);
    println!("current learning model: {}", stats.current[0]);
    dep.shutdown();
    Ok(())
}
