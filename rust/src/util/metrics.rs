//! Throughput meters and rolling statistics.
//!
//! rfps / cfps — the paper's two headline throughput counters (§4.4):
//! frames received from Actors vs frames consumed by the Learner.  All
//! counters are lock-free atomics so the hot paths never block on
//! metrics; a `MetricsHub` aggregates and renders Table-3-style rows.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Monotonic event counter with rate derivation.
pub struct Meter {
    count: AtomicU64,
    start: Mutex<Instant>,
}

impl Default for Meter {
    fn default() -> Self {
        Self::new()
    }
}

impl Meter {
    pub fn new() -> Self {
        Meter { count: AtomicU64::new(0), start: Mutex::new(Instant::now()) }
    }
    #[inline]
    pub fn add(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
    }
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
    /// Events per second since creation / last reset.
    pub fn rate(&self) -> f64 {
        let secs = self.start.lock().unwrap().elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.count() as f64 / secs
        }
    }
    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
        *self.start.lock().unwrap() = Instant::now();
    }
}

/// Windowed scalar statistic (mean/min/max over the recent window).
#[derive(Default)]
pub struct Rolling {
    inner: Mutex<RollingInner>,
}

#[derive(Default)]
struct RollingInner {
    window: Vec<f64>,
    cap: usize,
    next: usize,
    filled: bool,
}

impl Rolling {
    pub fn with_capacity(cap: usize) -> Self {
        Rolling {
            inner: Mutex::new(RollingInner {
                window: Vec::with_capacity(cap),
                cap: cap.max(1),
                next: 0,
                filled: false,
            }),
        }
    }
    pub fn push(&self, v: f64) {
        let mut g = self.inner.lock().unwrap();
        let cap = g.cap;
        if g.window.len() < cap {
            g.window.push(v);
        } else {
            let i = g.next;
            g.window[i] = v;
            g.next = (i + 1) % cap;
            g.filled = true;
        }
    }
    pub fn mean(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        if g.window.is_empty() {
            return 0.0;
        }
        g.window.iter().sum::<f64>() / g.window.len() as f64
    }
    pub fn minmax(&self) -> (f64, f64) {
        let g = self.inner.lock().unwrap();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &v in &g.window {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if g.window.is_empty() {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().window.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Named registry shared across modules (one per process).
#[derive(Default)]
pub struct MetricsHub {
    meters: Mutex<BTreeMap<String, std::sync::Arc<Meter>>>,
    rollings: Mutex<BTreeMap<String, std::sync::Arc<Rolling>>>,
}

impl MetricsHub {
    pub fn meter(&self, name: &str) -> std::sync::Arc<Meter> {
        self.meters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Meter::new()))
            .clone()
    }
    pub fn rolling(&self, name: &str) -> std::sync::Arc<Rolling> {
        self.rollings
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Rolling::with_capacity(256)))
            .clone()
    }
    /// "name=rate/s" report, sorted by name (used by the throughput table).
    pub fn report(&self) -> Vec<(String, f64)> {
        self.meters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, m)| (k.clone(), m.rate()))
            .collect()
    }
}

/// Simple wall-clock stopwatch used by the bench harness.
pub struct Stopwatch(Instant);

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_counts() {
        let m = Meter::new();
        m.add(3);
        m.add(4);
        assert_eq!(m.count(), 7);
        assert!(m.rate() > 0.0);
        m.reset();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn rolling_window_wraps() {
        let r = Rolling::with_capacity(3);
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.push(v);
        }
        // window now holds {4, 2, 3}
        assert_eq!(r.len(), 3);
        assert!((r.mean() - 3.0).abs() < 1e-9);
        assert_eq!(r.minmax(), (2.0, 4.0));
    }

    #[test]
    fn hub_shares_meters() {
        let hub = MetricsHub::default();
        hub.meter("rfps").add(10);
        assert_eq!(hub.meter("rfps").count(), 10);
        assert_eq!(hub.report().len(), 1);
    }
}
