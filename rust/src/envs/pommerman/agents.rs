//! Scripted Pommerman opponents.
//!
//! - [`SimpleAgent`]: re-implementation of the competition's rule-based
//!   builtin AI (bomb evasion via BFS, item pickup, wood bombing,
//!   opportunistic attacks).  The paper's Fig-4 left curve is win-rate
//!   against this agent.
//! - [`Navocado`]: stand-in for the NeurIPS-18 top learning agent (the
//!   real checkpoint is closed): SimpleAgent plus escape-checked bomb
//!   placement, enemy chasing, and teammate target splitting.  Fig-4
//!   right reports W/L/T against it.

use super::{
    action_delta, in_bounds, Pommerman, ACT_BOMB, ACT_DOWN, ACT_IDLE,
    ACT_LEFT, ACT_RIGHT, ACT_UP, BOMB_LIFE, SIZE,
};
use crate::util::rng::Pcg32;

const MOVES: [usize; 4] = [ACT_UP, ACT_DOWN, ACT_LEFT, ACT_RIGHT];

fn idx(x: i32, y: i32) -> usize {
    y as usize * SIZE + x as usize
}

/// BFS distances from `start` over currently-walkable cells; cells under
/// imminent blast (danger <= horizon) are impassable.
fn bfs(env: &Pommerman, start: (i32, i32), danger: &[i32], horizon: i32) -> Vec<i32> {
    let mut dist = vec![i32::MAX; SIZE * SIZE];
    let mut queue = std::collections::VecDeque::new();
    dist[idx(start.0, start.1)] = 0;
    queue.push_back(start);
    while let Some((x, y)) = queue.pop_front() {
        let d = dist[idx(x, y)];
        for (dx, dy) in [(1, 0), (-1, 0), (0, 1), (0, -1)] {
            let (nx, ny) = (x + dx, y + dy);
            if !in_bounds(nx, ny) || dist[idx(nx, ny)] != i32::MAX {
                continue;
            }
            if !env.passable(nx, ny) || env.flames[idx(nx, ny)] > 0 {
                continue;
            }
            // entering a cell whose blast fires before we'd leave is suicide
            if danger[idx(nx, ny)] <= horizon.min(d + 2) {
                continue;
            }
            dist[idx(nx, ny)] = d + 1;
            queue.push_back((nx, ny));
        }
    }
    dist
}

/// First move of a shortest path from `start` to any cell satisfying
/// `target`; None if unreachable.
fn step_toward<F: Fn(i32, i32) -> bool>(
    env: &Pommerman,
    start: (i32, i32),
    danger: &[i32],
    target: F,
) -> Option<usize> {
    let dist = bfs(env, start, danger, 2);
    let mut best: Option<((i32, i32), i32)> = None;
    for y in 0..SIZE as i32 {
        for x in 0..SIZE as i32 {
            if dist[idx(x, y)] != i32::MAX && target(x, y) {
                if best.map_or(true, |(_, bd)| dist[idx(x, y)] < bd) {
                    best = Some(((x, y), dist[idx(x, y)]));
                }
            }
        }
    }
    let (goal, _) = best?;
    if goal == start {
        return Some(ACT_IDLE);
    }
    // walk back from goal to start
    let mut cur = goal;
    loop {
        let d = dist[idx(cur.0, cur.1)];
        let mut prev = None;
        for (dx, dy) in [(1, 0), (-1, 0), (0, 1), (0, -1)] {
            let (px, py) = (cur.0 + dx, cur.1 + dy);
            if in_bounds(px, py) && dist[idx(px, py)] == d - 1 {
                prev = Some((px, py));
                break;
            }
        }
        let p = prev?;
        if p == start {
            for &a in &MOVES {
                let (dx, dy) = action_delta(a);
                if (start.0 + dx, start.1 + dy) == cur {
                    return Some(a);
                }
            }
            return None;
        }
        cur = p;
    }
}

/// Would placing a bomb at `pos` leave an escape route?
fn bomb_is_escapable(env: &Pommerman, who: usize, pos: (i32, i32)) -> bool {
    let mut sim_danger = env.danger_map();
    let blast = env.agents[who].blast;
    // overlay the hypothetical bomb's blast at BOMB_LIFE
    for (dx, dy) in [(0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)] {
        for r in 0..=blast {
            if r == 0 && (dx, dy) != (0, 0) {
                continue;
            }
            let (x, y) = (pos.0 + dx * r, pos.1 + dy * r);
            if !in_bounds(x, y) {
                break;
            }
            if env.board[idx(x, y)] == super::Cell::Rigid {
                break;
            }
            sim_danger[idx(x, y)] = sim_danger[idx(x, y)].min(BOMB_LIFE);
            if env.board[idx(x, y)] == super::Cell::Wood {
                break;
            }
            if (dx, dy) == (0, 0) {
                break;
            }
        }
    }
    // BFS: can we reach a safe cell within BOMB_LIFE steps?
    let dist = bfs(env, pos, &sim_danger, 0);
    for y in 0..SIZE as i32 {
        for x in 0..SIZE as i32 {
            let i = idx(x, y);
            if sim_danger[i] == i32::MAX
                && dist[i] != i32::MAX
                && dist[i] < BOMB_LIFE
            {
                return true;
            }
        }
    }
    false
}

pub trait ScriptedPolicy: Send {
    fn act(&mut self, env: &Pommerman, who: usize) -> usize;
    fn name(&self) -> &'static str;
}

pub struct RandomAgent {
    rng: Pcg32,
}

impl RandomAgent {
    pub fn new(seed: u64) -> Self {
        RandomAgent { rng: Pcg32::from_label(seed, "pom-random") }
    }
}

impl ScriptedPolicy for RandomAgent {
    fn act(&mut self, _env: &Pommerman, _who: usize) -> usize {
        self.rng.below(6) as usize
    }
    fn name(&self) -> &'static str {
        "random"
    }
}

pub struct SimpleAgent {
    rng: Pcg32,
}

impl SimpleAgent {
    pub fn new(seed: u64) -> Self {
        SimpleAgent { rng: Pcg32::from_label(seed, "pom-simple") }
    }

    fn safe_moves(&self, env: &Pommerman, who: usize, danger: &[i32]) -> Vec<usize> {
        let me = env.agents[who].pos;
        let mut out = Vec::new();
        for &a in &MOVES {
            let (dx, dy) = action_delta(a);
            let (nx, ny) = (me.0 + dx, me.1 + dy);
            if env.passable(nx, ny)
                && env.agent_at(nx, ny).is_none()
                && env.flames[idx(nx, ny)] == 0
                && danger[idx(nx, ny)] > 2
            {
                out.push(a);
            }
        }
        out
    }
}

impl ScriptedPolicy for SimpleAgent {
    fn act(&mut self, env: &Pommerman, who: usize) -> usize {
        let me = env.agents[who];
        if !me.alive {
            return ACT_IDLE;
        }
        let danger = env.danger_map();
        let my_i = idx(me.pos.0, me.pos.1);

        // 1. evade imminent blasts
        if danger[my_i] != i32::MAX {
            if let Some(a) = step_toward(env, me.pos, &danger, |x, y| {
                danger[idx(x, y)] == i32::MAX
            }) {
                return a;
            }
            let safe = self.safe_moves(env, who, &danger);
            if !safe.is_empty() {
                return *self.rng.choose(&safe);
            }
            return ACT_IDLE;
        }

        // 2. attack an adjacent enemy
        if me.ammo > 0 {
            let enemy_close = (0..4).any(|e| {
                e != who
                    && !env.same_team(who, e)
                    && env.agents[e].alive
                    && (env.agents[e].pos.0 - me.pos.0).abs()
                        + (env.agents[e].pos.1 - me.pos.1).abs()
                        <= 2
            });
            if enemy_close && bomb_is_escapable(env, who, me.pos) {
                return ACT_BOMB;
            }
        }

        // 3. pick up a nearby item
        if let Some(a) = step_toward(env, me.pos, &danger, |x, y| {
            env.items[idx(x, y)].is_some()
        }) {
            if a != ACT_IDLE {
                return a;
            }
        }

        // 4. bomb adjacent wood
        if me.ammo > 0 {
            let wood_adj = MOVES.iter().any(|&a| {
                let (dx, dy) = action_delta(a);
                let (nx, ny) = (me.pos.0 + dx, me.pos.1 + dy);
                in_bounds(nx, ny) && env.board[idx(nx, ny)] == super::Cell::Wood
            });
            if wood_adj && bomb_is_escapable(env, who, me.pos) {
                return ACT_BOMB;
            }
        }

        // 5. wander safely
        let safe = self.safe_moves(env, who, &danger);
        if safe.is_empty() {
            ACT_IDLE
        } else {
            *self.rng.choose(&safe)
        }
    }

    fn name(&self) -> &'static str {
        "simple"
    }
}

/// Stronger scripted agent standing in for the NeurIPS-18 "Navocado".
pub struct Navocado {
    inner: SimpleAgent,
}

impl Navocado {
    pub fn new(seed: u64) -> Self {
        Navocado { inner: SimpleAgent::new(seed ^ 0x6e61_766f) }
    }
}

impl ScriptedPolicy for Navocado {
    fn act(&mut self, env: &Pommerman, who: usize) -> usize {
        let me = env.agents[who];
        if !me.alive {
            return ACT_IDLE;
        }
        let danger = env.danger_map();
        let my_i = idx(me.pos.0, me.pos.1);

        // evasion first (shared with SimpleAgent)
        if danger[my_i] != i32::MAX {
            return self.inner.act(env, who);
        }

        // target selection: teammates split enemies (0 takes nearest,
        // 2 takes the other when both alive)
        let mut enemies: Vec<usize> = (0..4)
            .filter(|&e| e != who && !env.same_team(who, e) && env.agents[e].alive)
            .collect();
        enemies.sort_by_key(|&e| {
            (env.agents[e].pos.0 - me.pos.0).abs()
                + (env.agents[e].pos.1 - me.pos.1).abs()
        });
        let mate = Pommerman::teammate(who);
        let target = if enemies.len() >= 2
            && env.mode == super::Mode::Team
            && env.agents[mate].alive
            && who > mate
        {
            enemies[1]
        } else {
            enemies.first().copied().unwrap_or(who)
        };

        if target != who {
            let tp = env.agents[target].pos;
            let dist = (tp.0 - me.pos.0).abs() + (tp.1 - me.pos.1).abs();
            // in blast line and close: bomb (only if escapable)
            let aligned = (tp.0 == me.pos.0 && (tp.1 - me.pos.1).abs() <= me.blast)
                || (tp.1 == me.pos.1 && (tp.0 - me.pos.0).abs() <= me.blast);
            if me.ammo > 0 && aligned && dist <= me.blast
                && bomb_is_escapable(env, who, me.pos)
            {
                return ACT_BOMB;
            }
            // chase
            if dist > 2 {
                if let Some(a) = step_toward(env, me.pos, &danger, |x, y| {
                    (x - tp.0).abs() + (y - tp.1).abs() <= 1
                }) {
                    if a != ACT_IDLE {
                        return a;
                    }
                }
            }
        }
        // fall back to SimpleAgent behaviour (items, wood, wander)
        self.inner.act(env, who)
    }

    fn name(&self) -> &'static str {
        "navocado"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::MultiAgentEnv;

    fn play(
        mut mk0: impl FnMut(u64) -> Box<dyn ScriptedPolicy>,
        mut mk1: impl FnMut(u64) -> Box<dyn ScriptedPolicy>,
        games: u64,
    ) -> (f64, f64) {
        // team 0 (agents 0,2) uses mk0; team 1 (agents 1,3) uses mk1.
        let mut score0 = 0.0;
        for g in 0..games {
            let mut env = Pommerman::team(g);
            env.reset();
            let mut pols: Vec<Box<dyn ScriptedPolicy>> = vec![
                mk0(g * 4), mk1(g * 4 + 1), mk0(g * 4 + 2), mk1(g * 4 + 3),
            ];
            loop {
                let acts: Vec<usize> =
                    (0..4).map(|i| pols[i].act(&env, i)).collect();
                let s = env.step(&acts);
                if s.done {
                    score0 += s.info.outcome.unwrap()[0] as f64;
                    break;
                }
            }
        }
        (score0 / games as f64, 1.0 - score0 / games as f64)
    }

    #[test]
    fn simple_agent_survives_own_bombs() {
        // simple vs idle: simple agents should essentially never die to
        // their own bombs; give them at worst a high non-loss rate.
        let (s, _) = play(
            |s| Box::new(SimpleAgent::new(s)),
            |_| Box::new(IdleAgent),
            8,
        );
        assert!(s >= 0.5, "simple vs idle scored {s}");
    }

    #[test]
    fn simple_beats_random() {
        let (s, _) = play(
            |s| Box::new(SimpleAgent::new(s)),
            |s| Box::new(RandomAgent::new(s)),
            10,
        );
        assert!(s > 0.6, "simple vs random scored only {s}");
    }

    #[test]
    fn navocado_at_least_matches_simple() {
        let (n, _) = play(
            |s| Box::new(Navocado::new(s)),
            |s| Box::new(SimpleAgent::new(s)),
            16,
        );
        assert!(n >= 0.45, "navocado vs simple scored {n}");
    }

    struct IdleAgent;
    impl ScriptedPolicy for IdleAgent {
        fn act(&mut self, _e: &Pommerman, _w: usize) -> usize {
            ACT_IDLE
        }
        fn name(&self) -> &'static str {
            "idle"
        }
    }

    #[test]
    fn escape_check_rejects_corner_trap() {
        let mut env = Pommerman::team(0);
        env.reset();
        // box an agent into a 1-cell pocket: bombing would be suicide
        env.board.fill(super::super::Cell::Rigid);
        env.board[idx(1, 1)] = super::super::Cell::Passage;
        env.agents[0].pos = (1, 1);
        env.bombs.clear();
        assert!(!bomb_is_escapable(&env, 0, (1, 1)));
        // open a corridor longer than the blast: now escapable
        for x in 1..=8 {
            env.board[idx(x, 1)] = super::super::Cell::Passage;
        }
        for y in 1..=3 {
            env.board[idx(8, y)] = super::super::Cell::Passage;
        }
        assert!(bomb_is_escapable(&env, 0, (1, 1)));
    }
}
