//! SIGINT/SIGTERM → stop-flag bridge (no signal-handling crates in the
//! offline set; the libc `signal` symbol is declared directly since
//! libc is always linked on unix).  The handler only performs an atomic
//! store, which is async-signal-safe.  Standalone services and the
//! procs-mode supervisor poll the flag to drain sockets and exit
//! cleanly instead of being killed mid-frame.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

static STOP: AtomicBool = AtomicBool::new(false);
static INSTALL: Once = Once::new();

#[cfg(unix)]
fn install_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        STOP.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: `signal` is the libc symbol with its exact C signature;
    // `on_signal` is `extern "C"` and only performs an async-signal-safe
    // atomic store, and it outlives the process (a fn item).
    unsafe {
        let _ = signal(SIGINT, on_signal);
        let _ = signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
fn install_handlers() {}

/// Install the handlers (idempotent) and return the process-wide stop
/// flag.  SIGINT or SIGTERM flips it to `true`.
pub fn install() -> &'static AtomicBool {
    INSTALL.call_once(install_handlers);
    &STOP
}

/// The flag without installing handlers (tests, embedding).
pub fn stop_flag() -> &'static AtomicBool {
    &STOP
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `install` is idempotent and the flag starts clear.  (Actually
    /// raising a signal would race other tests in this process, so the
    /// handler path is exercised by the standalone-service integration
    /// test instead.)
    #[test]
    fn install_is_idempotent() {
        let a = install();
        let b = install();
        assert!(std::ptr::eq(a, b));
        assert!(std::ptr::eq(a, stop_flag()));
    }
}
