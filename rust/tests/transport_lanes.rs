//! Lane/TCP equivalence: the shared-memory lane must be invisible to the
//! protocol.  The same seeded request sequence is driven through a pure
//! TCP client and a lane client against one server; every reply must be
//! bit-identical.  No engine artifacts needed — the handler is a
//! deterministic function of the request.

use tleague::proto::{ModelKey, Msg};
use tleague::transport::{LaneMode, LaneOpts, RepServer, ReqClient};
use tleague::util::codec::Wire;

fn xorshift(s: &mut u64) -> u64 {
    *s ^= *s << 13;
    *s ^= *s >> 7;
    *s ^= *s << 17;
    *s
}

/// A pure function of the request — same inputs, same reply bytes,
/// whichever transport carried them.
fn serve_deterministic(bind: &str) -> RepServer {
    RepServer::serve_frames(bind, |msg| match msg {
        Msg::InferReq { key, obs, rows, .. } => {
            let logits: Vec<f32> =
                obs.iter().map(|x| x * 2.0 + key.version as f32).collect();
            let value: Vec<f32> =
                (0..rows).map(|r| obs[r as usize] - key.agent as f32).collect();
            Msg::InferResp { logits, value }.into()
        }
        Msg::Ping => Msg::Pong.into(),
        other => Msg::Err(format!("unexpected {other:?}")).into(),
    })
    .unwrap()
}

/// One seeded actor tick: mostly multi-row InferReqs, a heartbeat Ping
/// every 7th tick.
fn tick_request(s: &mut u64, tick: u32) -> Msg {
    if tick % 7 == 6 {
        return Msg::Ping;
    }
    let rows = 1 + (xorshift(s) % 4) as u32;
    let obs: Vec<f32> = (0..rows as usize * 8)
        .map(|_| (xorshift(s) % 1000) as f32 * 0.01 - 5.0)
        .collect();
    let key = ModelKey::new((xorshift(s) % 3) as u32, (xorshift(s) % 50) as u32);
    Msg::InferReq { key, obs, rows, trace: None }
}

#[test]
fn seeded_ticks_bit_identical_over_tcp_and_lane() {
    let server = serve_deterministic("127.0.0.1:0");
    let tcp = ReqClient::connect(&server.addr);
    let lane = ReqClient::connect_opts(
        &server.addr,
        LaneOpts { mode: LaneMode::On, dir: None, capacity: 0 },
    );
    let (mut s1, mut s2) = (0x9e3779b9u64, 0x9e3779b9u64);
    let mut infer_ticks = 0u64;
    for tick in 0..50u32 {
        let req_tcp = tick_request(&mut s1, tick);
        let req_lane = tick_request(&mut s2, tick);
        // both clients see the identical seeded request...
        assert_eq!(req_tcp.to_bytes(), req_lane.to_bytes(), "tick {tick}");
        if matches!(req_tcp, Msg::InferReq { .. }) {
            infer_ticks += 1;
        }
        let r_tcp = tcp.request(&req_tcp).unwrap();
        let r_lane = lane.request(&req_lane).unwrap();
        // ...and must get the identical reply bytes back
        assert_eq!(
            r_tcp.to_bytes(),
            r_lane.to_bytes(),
            "tick {tick}: lane reply diverged from TCP"
        );
        assert!(!matches!(r_tcp, Msg::Err(_)), "tick {tick}: {r_tcp:?}");
    }
    assert!(infer_ticks > 0);
    assert_eq!(
        lane.lane_requests.count(),
        50,
        "every request of the lane client must ride the ring"
    );
    assert_eq!(tcp.lane_requests.count(), 0, "TCP client must never use a lane");
}

/// Both client flavors hammer one server concurrently: the epoll core
/// serves the TCP conn while the lane thread serves the ring, with no
/// cross-talk between the two paths.
#[test]
fn lane_and_tcp_clients_share_one_server() {
    let server = serve_deterministic("127.0.0.1:0");
    let addr = server.addr.clone();
    let addr2 = addr.clone();
    let t_tcp = std::thread::spawn(move || {
        let c = ReqClient::connect(&addr);
        let mut s = 7u64;
        for tick in 0..25 {
            let req = tick_request(&mut s, tick);
            assert!(!matches!(c.request(&req).unwrap(), Msg::Err(_)));
        }
    });
    let t_lane = std::thread::spawn(move || {
        let c = ReqClient::connect_opts(
            &addr2,
            LaneOpts { mode: LaneMode::On, dir: None, capacity: 0 },
        );
        let mut s = 7u64;
        for tick in 0..25 {
            let req = tick_request(&mut s, tick);
            assert!(!matches!(c.request(&req).unwrap(), Msg::Err(_)));
        }
        assert_eq!(c.lane_requests.count(), 25);
    });
    t_tcp.join().unwrap();
    t_lane.join().unwrap();
}
