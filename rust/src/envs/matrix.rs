//! One-step matrix games (Rock-Paper-Scissors and friends).
//!
//! These validate the FSP argument of the paper's §3.1: independent RL
//! circulates over pure strategies on RPS while fictitious self-play
//! converges to the Nash equilibrium.  The obs is a constant vector
//! (one-step game: a single state); the episode ends after one joint
//! action, with the payoff as the reward.

use super::{Info, MultiAgentEnv, Step};
use crate::util::rng::Pcg32;

/// Two-player zero-sum matrix game.  `payoff[i][j]` is player 0's
/// payoff when p0 plays i and p1 plays j; player 1 receives the
/// negation (r^1 + r^2 = 0, the competitive mode of §3.1).
pub struct MatrixGame {
    pub name: &'static str,
    payoff: Vec<Vec<f32>>,
    obs_dim: usize,
    #[allow(dead_code)]
    rng: Pcg32,
    done: bool,
}

impl MatrixGame {
    pub fn new(name: &'static str, payoff: Vec<Vec<f32>>, seed: u64) -> Self {
        let n = payoff.len();
        assert!(payoff.iter().all(|row| row.len() == n));
        MatrixGame {
            name,
            payoff,
            obs_dim: 4,
            rng: Pcg32::from_label(seed, "matrix"),
            done: true,
        }
    }

    /// Rock-Paper-Scissors: the canonical circulating game.
    pub fn rps(seed: u64) -> Self {
        Self::new(
            "rps",
            vec![
                vec![0.0, -1.0, 1.0],
                vec![1.0, 0.0, -1.0],
                vec![-1.0, 1.0, 0.0],
            ],
            seed,
        )
    }

    /// Biased RPS (asymmetric payoffs, NE != uniform): rock wins double.
    pub fn biased_rps(seed: u64) -> Self {
        Self::new(
            "biased_rps",
            vec![
                vec![0.0, -1.0, 2.0],
                vec![1.0, 0.0, -1.0],
                vec![-2.0, 1.0, 0.0],
            ],
            seed,
        )
    }

    pub fn payoff(&self, a0: usize, a1: usize) -> f32 {
        self.payoff[a0][a1]
    }

    /// Expected payoff of mixed strategy `p` vs `q` (player-0 view).
    pub fn expected_payoff(&self, p: &[f64], q: &[f64]) -> f64 {
        let n = self.payoff.len();
        let mut total = 0.0;
        for i in 0..n {
            for j in 0..n {
                total += p[i] * q[j] * self.payoff[i][j] as f64;
            }
        }
        total
    }

    /// Exploitability of a symmetric strategy `p`: how much the best
    /// pure response earns against it.  0 at the NE of a symmetric
    /// zero-sum game; this is the convergence metric for experiment V1.
    pub fn exploitability(&self, p: &[f64]) -> f64 {
        let n = self.payoff.len();
        (0..n)
            .map(|br| {
                (0..n)
                    .map(|j| p[j] * -self.payoff[br][j] as f64)
                    .sum::<f64>()
                    // br is player-1's action: player-1 payoff = -payoff[j][br]
            })
            .fold(f64::NEG_INFINITY, f64::max)
            .max(
                (0..n)
                    .map(|br| {
                        (0..n)
                            .map(|j| p[j] * self.payoff[br][j] as f64)
                            .sum::<f64>()
                    })
                    .fold(f64::NEG_INFINITY, f64::max),
            )
    }
}

impl MultiAgentEnv for MatrixGame {
    fn n_agents(&self) -> usize {
        2
    }
    fn obs_dim(&self) -> usize {
        self.obs_dim
    }
    fn act_dim(&self) -> usize {
        self.payoff.len()
    }
    fn max_steps(&self) -> usize {
        1
    }

    fn reset(&mut self) -> Vec<Vec<f32>> {
        self.done = false;
        vec![vec![1.0, 0.0, 0.0, 0.0]; 2]
    }

    fn step(&mut self, actions: &[usize]) -> Step {
        assert!(!self.done, "step after done");
        assert_eq!(actions.len(), 2);
        self.done = true;
        let r0 = self.payoff[actions[0]][actions[1]];
        let outcome = if r0 > 0.0 {
            vec![1.0, 0.0]
        } else if r0 < 0.0 {
            vec![0.0, 1.0]
        } else {
            vec![0.5, 0.5]
        };
        Step {
            obs: vec![vec![1.0, 0.0, 0.0, 0.0]; 2],
            rewards: vec![r0, -r0],
            done: true,
            info: Info { outcome: Some(outcome), frags: None },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rps_is_zero_sum_and_cyclic() {
        let mut g = MatrixGame::rps(0);
        g.reset();
        let s = g.step(&[0, 1]); // rock vs paper: p1 wins
        assert_eq!(s.rewards, vec![-1.0, 1.0]);
        assert_eq!(s.info.outcome.unwrap(), vec![0.0, 1.0]);
        for a in 0..3 {
            for b in 0..3 {
                let g = MatrixGame::rps(0);
                assert_eq!(g.payoff(a, b), -g.payoff(b, a));
            }
        }
    }

    #[test]
    fn uniform_is_rps_nash() {
        let g = MatrixGame::rps(0);
        let uniform = [1.0 / 3.0; 3];
        assert!(g.exploitability(&uniform).abs() < 1e-9);
        // pure rock is exploitable by paper (payoff 1)
        assert!((g.exploitability(&[1.0, 0.0, 0.0]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn expected_payoff_symmetric_zero() {
        let g = MatrixGame::rps(0);
        let u = [1.0 / 3.0; 3];
        assert!(g.expected_payoff(&u, &u).abs() < 1e-12);
    }

    #[test]
    fn biased_rps_nash_not_uniform() {
        let g = MatrixGame::biased_rps(0);
        let uniform = [1.0 / 3.0; 3];
        assert!(g.exploitability(&uniform) > 0.05);
        // analytic NE of this biased game: (1/4, 1/2, 1/4)
        let ne = [0.25, 0.5, 0.25];
        assert!(g.exploitability(&ne).abs() < 1e-9);
    }
}
