//! kube-lite: spec-driven deployment supervisor (paper §3.4 substitute).
//!
//! Two deployment modes share one role-agnostic core:
//!
//!   - **thread** ([`Deployment`]): every role runs as a supervised
//!     thread in this process.  Actors get k8s-Deployment semantics:
//!     they auto-restart on panic/error and can be scaled at runtime.
//!   - **procs** ([`controller::Controller`] + [`worker`]): each role
//!     runs as its own OS process.  Workers register with the
//!     controller over the `transport` layer, heartbeat, and get their
//!     slot reassigned when they die (see DESIGN.md §Process
//!     deployment).
//!
//! [`CoreServices`] is the shared launch path: resume-from-snapshot,
//! M_M ModelPool replicas, the LeagueMgr, and the background
//! snapshotter — everything that is a *service* rather than a *role*.

pub mod chaos;
pub mod controller;
pub mod worker;

use crate::actor::{Actor, ActorConfig, PolicyBackend};
use crate::checkpoint::{merge_shard_models, CheckpointMgr, LeagueSnapshot};
use crate::config::RunConfig;
use crate::inference::{InfServer, InfServerConfig};
use crate::league::{LeagueConfig, LeagueMgrServer, LeagueStats};
use crate::learner::allreduce::Allreduce;
use crate::learner::{Learner, LearnerConfig, TrainStats};
use crate::model_pool::{
    self, MapHolder, ModelPoolServer, MoveStats, PoolOptions,
};
use crate::proto::LeagueReport;
use crate::runtime::Engine;
use crate::telemetry::{snapshot_role, trace, LeagueView};
use crate::util::metrics::MetricsHub;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Live status shared by a learner thread.
#[derive(Default)]
pub struct LearnerStatus {
    pub steps: AtomicU64,
    pub rfps_frames: AtomicU64,
    pub cfps_frames: AtomicU64,
    pub stats: Mutex<TrainStats>,
    pub done: AtomicBool,
}

/// The league's service plane: ModelPool replicas + LeagueMgr +
/// background snapshotter, with resume-from-snapshot.  Role-agnostic —
/// both the thread-mode [`Deployment`] and the procs-mode controller
/// launch exactly this, then attach their roles to it.
pub struct CoreServices {
    pub league: LeagueMgrServer,
    pub pools: Vec<ModelPoolServer>,
    pub pool_addrs: Vec<String>,
    /// the deployment's shard map: one holder shared by every
    /// in-process replica, the controller's rebalance path, and the
    /// snapshotter's placement-aware resume preload
    pub holder: Arc<MapHolder>,
    /// per-replica liveness, index == shard slot.  [`kill_pool`]
    /// (Self::kill_pool) flips a flag instead of removing the server so
    /// slot indices — and therefore ring placement — stay stable.
    pub pool_live: Arc<Vec<AtomicBool>>,
    snapshotter: Option<std::thread::JoinHandle<()>>,
    /// raised only after every writer of league/pool state is quiesced,
    /// so the snapshotter's final save is complete
    snap_stop: Arc<AtomicBool>,
    /// chaos drills: a simulated crash must NOT get the clean-shutdown
    /// final save — recovery has to come from the last periodic (or
    /// forced) snapshot, exactly like a real SIGKILL
    snap_skip_final: Arc<AtomicBool>,
}

impl CoreServices {
    /// Launch pools + league (+ snapshotter if `cfg.checkpoint_dir`),
    /// binding on `bind_host` with ephemeral ports.  `hp_layout` /
    /// `hp_default` come from the artifact manifest; `cfg.hp_overrides`
    /// are applied here by layout position.
    ///
    /// With `cfg.resume`, the latest snapshot in that directory seeds
    /// the LeagueMgr (pool/payoff/Elo/hyper/RNG/counters) and
    /// pre-populates every ModelPool replica.
    pub fn start(
        cfg: &RunConfig,
        bind_host: &str,
        hp_layout: Vec<String>,
        mut hp_default: Vec<f32>,
    ) -> Result<CoreServices> {
        cfg.validate()?;
        let resume_snap: Option<LeagueSnapshot> = match &cfg.resume {
            Some(dir) => Some(
                CheckpointMgr::open(dir, cfg.checkpoint_keep)?
                    .load_latest()?
                    .with_context(|| format!("resume: no snapshot in {dir}"))?,
            ),
            None => None,
        };

        // spill directories live next to the snapshots (or under the
        // resume dir when the run isn't writing new checkpoints)
        let spill_root: Option<PathBuf> = cfg
            .checkpoint_dir
            .as_ref()
            .or(cfg.resume.as_ref())
            .map(PathBuf::from);
        // every client built in this process (and, via RunSlice, in the
        // workers) derives placement with the run's replication factor
        model_pool::set_default_replication(cfg.effective_replication());
        let bind = format!("{bind_host}:0");
        // the map exists before the ephemeral ports do: placement is
        // index-keyed, so placeholder addresses yield the identical
        // ring and are swapped for the real ones below without a
        // version bump (workers derive the same v1 map from the
        // assignment's address list)
        let placeholders: Vec<String> =
            (0..cfg.model_pools).map(|i| format!("pending-{i}")).collect();
        let holder = Arc::new(MapHolder::new(model_pool::shard::bootstrap_map(
            &placeholders,
            cfg.effective_replication() as u32,
        )));
        let pools: Vec<ModelPoolServer> = (0..cfg.model_pools)
            .map(|i| {
                ModelPoolServer::start_sharded(
                    &bind,
                    PoolOptions {
                        spill_dir: spill_root
                            .as_ref()
                            .map(|d| d.join(format!("spill-{i}"))),
                        mem_budget: cfg.pool_mem_budget_bytes,
                    },
                    holder.clone(),
                    i as u32,
                )
            })
            .collect::<Result<_>>()?;
        let pool_addrs: Vec<String> = pools.iter().map(|p| p.addr.clone()).collect();
        holder.set_addrs(pool_addrs.clone());
        let pool_live: Arc<Vec<AtomicBool>> =
            Arc::new(pools.iter().map(|_| AtomicBool::new(true)).collect());
        if let Some(snap) = &resume_snap {
            // placement-aware preload: each blob lands only on its R
            // owners, so a resumed deployment starts with exactly the
            // layout a fresh run converges to
            let (_, ring) = holder.get();
            for (i, p) in pools.iter().enumerate() {
                let mine: Vec<_> = snap
                    .models
                    .iter()
                    .filter(|b| ring.is_owner(b.key.agent, i as u32))
                    .cloned()
                    .collect();
                p.preload(&mine);
            }
        }

        for (k, v) in &cfg.hp_overrides {
            if let Some(i) = hp_layout.iter().position(|n| n == k) {
                hp_default[i] = *v;
            }
        }
        let league = LeagueMgrServer::start_with(
            &bind,
            LeagueConfig {
                n_agents: cfg.n_agents,
                n_opponents: cfg.effective_opponents(),
                game_mgr: cfg.game_mgr.clone(),
                hp_layout,
                hp_default,
                seed: cfg.seed,
            },
            resume_snap.as_ref(),
        )?;

        // ---- background snapshotter -----------------------------------
        // periodically persists league + pool state; writes once more on
        // shutdown so even a clean exit is resumable.
        let snap_stop = Arc::new(AtomicBool::new(false));
        let snap_skip_final = Arc::new(AtomicBool::new(false));
        let snapshotter = match &cfg.checkpoint_dir {
            Some(dir) => {
                let mgr = CheckpointMgr::open(dir, cfg.checkpoint_keep)?;
                let snap_league = league.snapshot_fn();
                // one blob source per replica: the snapshot is the
                // deduplicated union of every LIVE shard, so it stays
                // complete across kill:pool failovers (R >= 2 keeps a
                // surviving copy of everything)
                let snap_blob_fns: Vec<_> =
                    pools.iter().map(|p| p.blobs_fn()).collect();
                let live2 = pool_live.clone();
                let stop2 = snap_stop.clone();
                let skip2 = snap_skip_final.clone();
                let every = Duration::from_secs(cfg.checkpoint_every_secs);
                Some(
                    std::thread::Builder::new()
                        .name("snapshotter".into())
                        .spawn(move || {
                            let save = |mgr: &CheckpointMgr| {
                                let mut snap = snap_league();
                                snap.models = merge_shard_models(
                                    snap_blob_fns
                                        .iter()
                                        .enumerate()
                                        .filter(|(i, _)| {
                                            live2[*i].load(Ordering::Relaxed)
                                        })
                                        .map(|(_, f)| f())
                                        .collect(),
                                );
                                if let Err(e) = mgr.save(&snap) {
                                    eprintln!("snapshot failed: {e:#}");
                                }
                            };
                            let mut last = Instant::now();
                            while !stop2.load(Ordering::Relaxed) {
                                std::thread::sleep(Duration::from_millis(25));
                                if last.elapsed() >= every {
                                    save(&mgr);
                                    last = Instant::now();
                                }
                            }
                            if !skip2.load(Ordering::Relaxed) {
                                save(&mgr);
                            }
                        })?,
                )
            }
            None => None,
        };

        Ok(CoreServices {
            league,
            pools,
            pool_addrs,
            holder,
            pool_live,
            snapshotter,
            snap_stop,
            snap_skip_final,
        })
    }

    /// The deduplicated union of every live shard's blobs — the league's
    /// complete model set regardless of placement.
    fn live_union(&self) -> Vec<crate::proto::ModelBlob> {
        merge_shard_models(
            self.pools
                .iter()
                .enumerate()
                .filter(|(i, _)| self.pool_live[*i].load(Ordering::Relaxed))
                .map(|(_, p)| p.all_blobs())
                .collect(),
        )
    }

    /// Chaos drill: kill the highest-index live ModelPool replica and
    /// run the real failover path — close its port, tombstone the shard
    /// map (version bump; clients learn via `WrongShard` piggyback or
    /// refresh), rebalance the survivors so every agent is back at R
    /// owners, and check the union of live stores is bit-exact with the
    /// pre-kill state (R >= 2 guarantees a surviving copy of every
    /// blob).  Returns the downed address, the transfer stats, and the
    /// bit-exactness verdict; None when fewer than two replicas are
    /// live (replica 0 is never killed — its spill dir may back a
    /// resume).
    pub fn kill_pool(&mut self) -> Option<(String, MoveStats, bool)> {
        let live_idx: Vec<usize> = (0..self.pools.len())
            .filter(|&i| self.pool_live[i].load(Ordering::Relaxed))
            .collect();
        if live_idx.len() < 2 {
            return None;
        }
        let victim = *live_idx.last().unwrap();
        let before = self.live_union();
        self.pools[victim].shutdown();
        self.pool_live[victim].store(false, Ordering::Relaxed);
        let (old_map, _) = self.holder.get();
        let new_map = model_pool::shard::without_replica(&old_map, victim as u32);
        self.holder.install(new_map.clone());
        let live_flags: Vec<bool> = self
            .pool_live
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let moved =
            model_pool::rebalance(&self.pools, &live_flags, &old_map, &new_map);
        // containment, not equality: a learner may legitimately land a
        // new model during the failover window — bit-exact means every
        // PRE-KILL blob survived byte-for-byte, not that writes paused
        let after = self.live_union();
        let bit_exact = before.iter().all(|b| after.contains(b));
        Some((self.pools[victim].addr.clone(), moved, bit_exact))
    }

    /// Force a snapshot right now (tests / operator tooling); returns
    /// the path written.  Requires `cfg.checkpoint_dir`.
    pub fn snapshot_now(&self, cfg: &RunConfig) -> Result<PathBuf> {
        let dir = cfg
            .checkpoint_dir
            .as_ref()
            .context("snapshot_now requires cfg.checkpoint_dir")?;
        let mgr = CheckpointMgr::open(dir, cfg.checkpoint_keep)?;
        let mut snap = self.league.snapshot();
        snap.models = self.live_union();
        mgr.save(&snap)
    }

    /// Stop the snapshotter (final save included).  Call only after the
    /// last writer of league/pool state has quiesced — the final
    /// snapshot must include the learners' last frozen models.
    pub fn shutdown(&mut self) {
        self.snap_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.snapshotter.take() {
            h.join().ok();
        }
    }

    /// Simulate a SIGKILL of the service plane (chaos drills): close
    /// the league and pool ports immediately and SKIP the snapshotter's
    /// final save — a real crash never gets one.  Recovery must come
    /// from the last periodic (or [`snapshot_now`](Self::snapshot_now))
    /// snapshot, which is exactly the invariant the drills verify.
    pub fn crash(&mut self) {
        self.snap_skip_final.store(true, Ordering::Relaxed);
        self.snap_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.snapshotter.take() {
            h.join().ok();
        }
        self.league.shutdown();
        for p in &mut self.pools {
            p.shutdown();
        }
    }
}

/// Rewrite the host part of a bound address for advertisement to peers.
/// Binding 0.0.0.0/:: makes the kernel's local_addr useless to remote
/// machines; with an advertise host the service is published as
/// `<advertise_host>:<bound port>` instead.
pub fn advertised(addr: &str, advertise_host: Option<&str>) -> String {
    match (advertise_host, addr.rsplit_once(':')) {
        (Some(h), Some((_, port))) => format!("{h}:{port}"),
        _ => addr.to_string(),
    }
}

/// One learner's thread body, shared by both deployment modes: train to
/// `total` steps, mirror progress into `status`, then hold the data
/// port open until `stop` so actors don't error out mid-shutdown.
/// `hub` routes the learner's counters into the telemetry plane.
#[allow(clippy::too_many_arguments)]
pub fn learner_thread(
    lcfg: LearnerConfig,
    engine: Arc<Engine>,
    pool_addrs: Vec<String>,
    league_addr: String,
    group: Option<Arc<Allreduce>>,
    status: Arc<LearnerStatus>,
    stop: Arc<AtomicBool>,
    total: u64,
    addr_tx: std::sync::mpsc::Sender<String>,
    hub: Option<Arc<MetricsHub>>,
) -> Result<()> {
    let mut learner =
        Learner::new(lcfg, engine, &pool_addrs, &league_addr, group)?;
    if let Some(h) = &hub {
        learner.use_hub(h);
    }
    addr_tx.send(learner.data_addr()).ok();
    while learner.steps < total && !stop.load(Ordering::Relaxed) {
        learner.train_once()?;
        status.steps.store(learner.steps, Ordering::Relaxed);
        status
            .rfps_frames
            .store(learner.rfps.count(), Ordering::Relaxed);
        status
            .cfps_frames
            .store(learner.cfps.count(), Ordering::Relaxed);
        *status.stats.lock().unwrap() = learner.last_stats.clone();
    }
    status.done.store(true, Ordering::Relaxed);
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(10));
    }
    Ok(())
}

/// Build and drive one Actor until `stop` (or error).  Picks the
/// backend from `inf_addr` and fills in the manifest `train_t` the
/// Remote backend requires.  Shared by both deployment modes.  `hub`
/// routes the actor's frame/episode counters into the telemetry plane.
#[allow(clippy::too_many_arguments)]
pub fn run_actor(
    mut cfg: ActorConfig,
    envs_per_actor: usize,
    inf_addr: Option<&str>,
    lanes: crate::transport::LaneOpts,
    engine: &Arc<Engine>,
    league_addr: &str,
    pool_addrs: &[String],
    data_addr: &str,
    stop: &AtomicBool,
    hub: Option<&MetricsHub>,
) -> Result<()> {
    let backend = match inf_addr {
        Some(addr) => {
            cfg.train_t = engine
                .manifest
                .env(crate::envs::manifest_name(&cfg.env))
                .map(|m| m.train_t)
                .unwrap_or(16);
            PolicyBackend::Remote(crate::transport::ReqClient::connect_opts(
                addr, lanes,
            ))
        }
        None => PolicyBackend::Local(engine.clone()),
    };
    let mut actor = Actor::new_vec(
        cfg,
        envs_per_actor.max(1),
        backend,
        league_addr,
        pool_addrs,
        data_addr,
    )?;
    if let Some(h) = hub {
        actor.use_hub(h);
    }
    actor.run(u64::MAX, stop)?;
    Ok(())
}

pub struct Deployment {
    pub cfg: RunConfig,
    pub engine: Arc<Engine>,
    pub core: CoreServices,
    pub inf_addrs: Vec<String>,
    inf_servers: Vec<InfServer>,
    pub learner_status: Vec<Arc<LearnerStatus>>,
    learner_handles: Vec<std::thread::JoinHandle<Result<()>>>,
    /// one allreduce group per agent, retained so shutdown can poison
    /// them — a rank blocked in reduce would otherwise hang the join
    learner_groups: Vec<Arc<Allreduce>>,
    data_addrs: Vec<String>,
    actor_stop: Arc<AtomicBool>,
    actor_handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    pub restarts: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    next_actor_id: AtomicU64,
    /// telemetry: one hub per role instance, merged through the SAME
    /// `LeagueView` code path procs mode uses (snapshot → ingest →
    /// report), so thread-mode runs report identically
    view: Arc<LeagueView>,
    hubs: Mutex<Vec<(&'static str, u32, Arc<MetricsHub>)>>,
}

impl Deployment {
    /// Launch everything declared by `cfg` as threads.  Returns once all
    /// services are up and actors are running.
    pub fn start(cfg: RunConfig, engine: Arc<Engine>) -> Result<Deployment> {
        let core = CoreServices::start(
            &cfg,
            "127.0.0.1",
            engine.manifest.hp_layout.clone(),
            engine.manifest.default_hp(),
        )?;

        let stop = Arc::new(AtomicBool::new(false));
        let actor_stop = Arc::new(AtomicBool::new(false));
        trace::set_slow_ms(cfg.trace_slow_ms);
        let manifest_env = crate::envs::manifest_name(&cfg.env).to_string();
        let mut hubs: Vec<(&'static str, u32, Arc<MetricsHub>)> = core
            .pools
            .iter()
            .enumerate()
            .map(|(i, p)| ("model-pool", i as u32, p.hub().clone()))
            .collect();
        // thread mode shares one process, so one fault plan covers every
        // role; its counters get their own hub in the merged report
        if let Some(spec) = &cfg.faults {
            crate::transport::fault::set_role("deployment");
            crate::transport::fault::install_spec(cfg.fault_seed, spec)?;
            let fh = Arc::new(MetricsHub::default());
            fh.register(
                "faults_injected",
                crate::transport::fault::injected_meter(),
            );
            fh.register(
                "recoveries",
                crate::transport::fault::recovered_meter(),
            );
            hubs.push(("deployment", 0, fh));
        }

        // ---- learners -------------------------------------------------
        let mut learner_status = Vec::new();
        let mut learner_handles = Vec::new();
        let mut learner_groups = Vec::new();
        let mut data_addrs = Vec::new();
        for agent in 0..cfg.n_agents {
            let group = Allreduce::new(cfg.learners_per_agent);
            learner_groups.push(group.clone());
            for rank in 0..cfg.learners_per_agent {
                let status = Arc::new(LearnerStatus::default());
                learner_status.push(status.clone());
                let hub = Arc::new(MetricsHub::default());
                hubs.push(("learner", learner_handles.len() as u32, hub.clone()));
                let (tx, rx) = std::sync::mpsc::channel::<String>();
                let lcfg = LearnerConfig {
                    env: manifest_env.clone(),
                    agent,
                    rank,
                    algo: cfg.algo.clone(),
                    replay_mode: cfg.replay_mode(),
                    publish_every: cfg.publish_every,
                    period_steps: cfg.period_steps,
                    replay_cap: 8192,
                    seed: cfg.seed + agent as u64 * 100 + rank as u64,
                    data_bind: "127.0.0.1:0".into(),
                };
                let engine = engine.clone();
                let pool_addrs2 = core.pool_addrs.clone();
                let league_addr = core.league.addr.clone();
                let group = group.clone();
                let stop2 = stop.clone();
                let total = cfg.total_steps;
                let status2 = status.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("learner-{agent}-{rank}"))
                    .spawn(move || -> Result<()> {
                        learner_thread(
                            lcfg,
                            engine,
                            pool_addrs2,
                            league_addr,
                            Some(group),
                            status2,
                            stop2,
                            total,
                            tx,
                            Some(hub),
                        )
                    })?;
                learner_handles.push(handle);
                data_addrs.push(rx.recv_timeout(Duration::from_secs(30))?);
            }
        }

        // ---- inference servers ----------------------------------------
        let mut inf_servers = Vec::new();
        for _ in 0..cfg.inf_servers {
            let m = engine.manifest.env(&manifest_env)?;
            inf_servers.push(InfServer::start(
                "127.0.0.1:0",
                InfServerConfig {
                    env: manifest_env.clone(),
                    batch: m.infer_b,
                    max_wait: Duration::from_micros(cfg.infer_max_wait_us),
                    refresh: Duration::from_millis(cfg.infer_refresh_ms),
                    net_threads: cfg.net_threads,
                },
                engine.clone(),
                &core.pool_addrs,
            )?);
        }
        let inf_addrs: Vec<String> =
            inf_servers.iter().map(|s| s.addr.clone()).collect();
        for (i, s) in inf_servers.iter().enumerate() {
            hubs.push(("inf-server", i as u32, s.hub.clone()));
        }

        let deployment = Deployment {
            cfg,
            engine,
            core,
            inf_addrs,
            inf_servers,
            learner_status,
            learner_handles,
            learner_groups,
            data_addrs,
            actor_stop,
            actor_handles: Mutex::new(Vec::new()),
            restarts: Arc::new(AtomicU64::new(0)),
            stop,
            next_actor_id: AtomicU64::new(0),
            view: Arc::new(LeagueView::default()),
            hubs: Mutex::new(hubs),
        };

        // ---- actors (M_A per learner) ----------------------------------
        for li in 0..deployment.data_addrs.len() {
            let agent = (li / deployment.cfg.learners_per_agent) as u32;
            for _ in 0..deployment.cfg.actors_per_learner {
                deployment.spawn_actor(agent, li);
            }
        }
        Ok(deployment)
    }

    pub fn league(&self) -> &LeagueMgrServer {
        &self.core.league
    }

    pub fn pool_addrs(&self) -> &[String] {
        &self.core.pool_addrs
    }

    /// Scale up: add one supervised actor feeding learner `li`.
    pub fn spawn_actor(&self, agent: u32, li: usize) {
        let id = self.next_actor_id.fetch_add(1, Ordering::Relaxed);
        let cfg = ActorConfig {
            env: self.cfg.env.clone(),
            actor_id: format!("{agent}/a{id}"),
            seed: self.cfg.seed * 1000 + id,
            gamma: self.cfg.gamma,
            refresh_every: self.cfg.refresh_every,
            train_t: 0,
            trace_sample: self.cfg.trace_sample as f32,
        };
        let engine = self.engine.clone();
        let league_addr = self.core.league.addr.clone();
        let pool_addrs = self.core.pool_addrs.clone();
        let data_addr = self.data_addrs[li].clone();
        let inf_addr = self
            .inf_addrs
            .get(id as usize % self.inf_addrs.len().max(1))
            .cloned();
        let stop = self.actor_stop.clone();
        let restarts = self.restarts.clone();
        let envs_per_actor = self.cfg.envs_per_actor.max(1);
        let lanes = crate::transport::LaneOpts::from_config(
            &self.cfg.local_lanes,
            self.cfg.shm_dir.as_deref().unwrap_or(""),
        );
        let hub = Arc::new(MetricsHub::default());
        self.hubs
            .lock()
            .unwrap()
            .push(("actor", id as u32, hub.clone()));
        let handle = std::thread::Builder::new()
            .name(format!("actor-{}", cfg.actor_id))
            .spawn(move || {
                // k8s Deployment semantics: restart on any failure
                while !stop.load(Ordering::Relaxed) {
                    let run = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| -> Result<()> {
                            run_actor(
                                cfg.clone(),
                                envs_per_actor,
                                inf_addr.as_deref(),
                                lanes.clone(),
                                &engine,
                                &league_addr,
                                &pool_addrs,
                                &data_addr,
                                &stop,
                                Some(&hub),
                            )
                        }),
                    );
                    match run {
                        Ok(Ok(())) => break, // clean stop
                        Ok(Err(_)) | Err(_) => {
                            if stop.load(Ordering::Relaxed) {
                                break; // failures during shutdown are expected
                            }
                            restarts.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(Duration::from_millis(50));
                        }
                    }
                }
            })
            .expect("spawn actor");
        self.actor_handles.lock().unwrap().push(handle);
    }

    pub fn league_stats(&self) -> LeagueStats {
        self.core.league.stats()
    }

    /// Merged league telemetry: drain every role hub's interval into
    /// the shared [`LeagueView`] and derive the report — the identical
    /// snapshot/merge path the procs-mode controller runs, minus the
    /// wire hop.  Call periodically from ONE reporter (snapshots drain
    /// the interval deltas).
    pub fn telemetry_report(&self) -> LeagueReport {
        for (role, slot, hub) in self.hubs.lock().unwrap().iter() {
            self.view.ingest(&snapshot_role(hub, role, *slot));
        }
        // thread mode: every role shares this process's flight recorder
        self.view.ingest_spans(&trace::recorder().drain(1024));
        self.view.report()
    }

    /// Merged flight recorder (spans of every role), for the Chrome
    /// trace export at the end of a thread-mode run.
    pub fn trace_spans(&self) -> Vec<crate::proto::SpanRec> {
        self.view.spans()
    }

    /// Force a snapshot right now (tests / operator tooling); returns the
    /// path written.  Requires `checkpoint_dir`.
    pub fn snapshot_now(&self) -> Result<PathBuf> {
        self.core.snapshot_now(&self.cfg)
    }

    pub fn learners_done(&self) -> bool {
        self.learner_status
            .iter()
            .all(|s| s.done.load(Ordering::Relaxed))
    }

    pub fn total_learner_steps(&self) -> u64 {
        self.learner_status
            .iter()
            .map(|s| s.steps.load(Ordering::Relaxed))
            .sum()
    }

    /// Block until all learners hit total_steps (or `timeout`).
    pub fn wait(&self, timeout: Duration) -> bool {
        let start = std::time::Instant::now();
        while !self.learners_done() {
            if start.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        true
    }

    /// Stop everything: actors first, then learners/services.
    pub fn shutdown(&mut self) {
        self.actor_stop.store(true, Ordering::Relaxed);
        for h in self.actor_handles.lock().unwrap().drain(..) {
            h.join().ok();
        }
        self.stop.store(true, Ordering::Relaxed);
        // mid-run teardown (Drop on a failing test): a rank blocked in
        // reduce waits for peers that already saw `stop` — poison wakes
        // it so the join below cannot hang
        for g in &self.learner_groups {
            g.poison();
        }
        for h in self.learner_handles.drain(..) {
            let _ = h.join();
        }
        // learners are fully stopped: everything they will ever publish is
        // in the pools, so the snapshotter's final save is complete
        self.core.shutdown();
        for s in self.inf_servers.iter_mut() {
            s.shutdown();
        }
    }
}

impl Drop for Deployment {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn engine() -> Option<Arc<Engine>> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Arc::new(Engine::load(dir).unwrap()))
    }

    #[test]
    fn advertised_rewrites_host_only_when_asked() {
        assert_eq!(advertised("0.0.0.0:4321", Some("node7")), "node7:4321");
        assert_eq!(advertised("127.0.0.1:80", Some("10.0.0.5")), "10.0.0.5:80");
        assert_eq!(advertised("0.0.0.0:4321", None), "0.0.0.0:4321");
        // no port separator: left untouched rather than mangled
        assert_eq!(advertised("garbage", Some("h")), "garbage");
    }

    /// Vectorized actors (`envs_per_actor > 1`) drive a full league run
    /// through the same deployment path.
    #[test]
    fn deployment_runs_vectorized_actors() {
        let Some(engine) = engine() else { return };
        let mut cfg = RunConfig::default();
        cfg.env = "rps".into();
        cfg.total_steps = 4;
        cfg.period_steps = 2;
        cfg.actors_per_learner = 1;
        cfg.envs_per_actor = 4;
        let mut dep = Deployment::start(cfg, engine).unwrap();
        assert!(dep.wait(Duration::from_secs(120)), "did not finish");
        let stats = dep.league_stats();
        assert!(stats.episodes > 0);
        dep.shutdown();
    }

    #[test]
    fn deployment_runs_to_completion() {
        let Some(engine) = engine() else { return };
        let mut cfg = RunConfig::default();
        cfg.env = "rps".into();
        cfg.total_steps = 6;
        cfg.period_steps = 3;
        cfg.actors_per_learner = 2;
        let mut dep = Deployment::start(cfg, engine).unwrap();
        assert!(dep.wait(Duration::from_secs(120)), "did not finish");
        assert_eq!(dep.total_learner_steps(), 6);
        let stats = dep.league_stats();
        assert!(stats.pool_size >= 2);
        // thread mode reports through the same snapshot/merge path as
        // the procs controller: actors and learners show up with
        // nonzero run totals
        let tele = dep.telemetry_report();
        let get = |role: &str, k: &str| {
            tele.roles
                .iter()
                .find(|r| r.role == role)
                .and_then(|r| r.totals.iter().find(|(n, _)| n == k))
                .map(|(_, v)| *v)
                .unwrap_or(0)
        };
        assert!(get("actor", "env_frames") > 0, "{tele:?}");
        assert!(get("learner", "consumed_frames") > 0, "{tele:?}");
        assert!(get("model-pool", "reads") > 0, "{tele:?}");
        dep.shutdown();
    }
}
