//! Run configuration: the yml.jinja2-equivalent of the paper's §3.4.
//!
//! A JSON spec declares the whole training run — env, module replica
//! counts (M_A actors per learner, M_L learners, M_M model pools, M_G
//! learning agents), algorithm + sampler choices, and hyper-parameter
//! overrides.  The kube-lite orchestrator consumes this to launch the
//! league, mirroring "I want 56 Learners and 8 InfServers, each Learner
//! corresponds to 16 actors ..." from the paper.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub env: String,
    /// parallel learning agents (M_G)
    pub n_agents: u32,
    /// learners per agent (M_L)
    pub learners_per_agent: usize,
    /// actors per learner (M_A)
    pub actors_per_learner: usize,
    /// concurrent episodes per actor (vectorized rollouts: every actor
    /// tick batches all slots' observations into one forward pass per
    /// model; 1 = the classic single-env actor)
    pub envs_per_actor: usize,
    /// model-pool replicas (M_M)
    pub model_pools: usize,
    pub inf_servers: usize,
    pub game_mgr: String,
    pub algo: String,
    pub opponents_per_episode: usize,
    pub gamma: f32,
    pub publish_every: u64,
    pub period_steps: u64,
    pub total_steps: u64,
    pub replay_mode: String, // "blocking" | "ratio:<n>"
    pub seed: u64,
    pub hp_overrides: BTreeMap<String, f32>,
    /// directory for periodic league snapshots (None = not durable)
    pub checkpoint_dir: Option<String>,
    /// seconds between background snapshots
    pub checkpoint_every_secs: u64,
    /// how many snapshots to retain
    pub checkpoint_keep: usize,
    /// ModelPool resident-byte budget (0 = unbounded, no spilling)
    pub pool_mem_budget_bytes: usize,
    /// restart from the latest snapshot in this directory
    pub resume: Option<String>,
    /// actor param-refresh cadence in episodes (delta-aware: an
    /// unchanged in-training model costs an O(1) NotModified)
    pub refresh_every: u32,
    /// InfServer partial-batch deadline in microseconds
    pub infer_max_wait_us: u64,
    /// InfServer in-training param cache TTL in milliseconds
    pub infer_refresh_ms: u64,
    /// deployment mode: "thread" (every role a thread in this process,
    /// the default) or "procs" (one supervised OS process per role
    /// worker, coordinated by the controller service)
    pub mode: String,
    /// bind address of the controller service (procs mode).  Use a
    /// routable host (not 127.0.0.1) for multi-machine deployments.
    pub controller_bind: String,
    /// host peers should use to reach services bound on this machine.
    /// Required in practice when binding 0.0.0.0/:: — the kernel's
    /// local_addr ("0.0.0.0:port") is useless to a remote worker.
    pub advertise_host: Option<String>,
    /// worker heartbeat cadence in milliseconds (procs mode)
    pub heartbeat_ms: u64,
    /// silence after which the controller declares a worker dead and
    /// frees its slot for reassignment
    pub heartbeat_timeout_ms: u64,
    /// seconds between league telemetry reports (the periodic one-line
    /// throughput summary, and the JSONL cadence when enabled)
    pub stats_every_secs: u64,
    /// append one merged-league-telemetry JSON object per report
    /// interval to this file (None = no trajectory file)
    pub stats_jsonl: Option<String>,
    /// fraction of actor ticks that carry a trace context (0.0 = spans
    /// off; latency histograms record regardless)
    pub trace_sample: f64,
    /// requests slower than this land in the flight recorder's
    /// slow-request log even when unsampled elsewhere
    pub trace_slow_ms: u64,
    /// seed of the deterministic fault-injection plan — every process
    /// in the run derives identical per-site RNG streams from it
    pub fault_seed: u64,
    /// fault-injection spec (`kind:target@prob[+delay_ms]`, comma
    /// separated — see `transport::fault`); None = injection disabled,
    /// the hot-path check compiles down to one relaxed load
    pub faults: Option<String>,
    /// chaos kill schedule for procs mode (`kill:<role>@<ms>`, comma
    /// separated — see `orchestrator::chaos`); None = no chaos
    pub chaos: Option<String>,
    /// shared-memory lane policy for colocated actor↔inf-server pairs:
    /// "auto" (lanes when the endpoint is loopback), "on", or "off"
    pub local_lanes: String,
    /// directory for lane ring files (None = /dev/shm, falling back to
    /// the system temp dir)
    pub shm_dir: Option<String>,
    /// event-loop threads per transport server (0 = auto: min(2, cores))
    pub net_threads: usize,
    /// ModelPool replication factor R: each agent's models live on R of
    /// the `model_pools` replicas (consistent-hash sharding).  Clamped
    /// to the replica count at deploy time, so the single-replica
    /// default behaves exactly like the unsharded seed.
    pub pool_replication: usize,
    /// closed-loop autoscaling of actor / inf-server slots (procs mode
    /// only): the controller's policy loop reads league telemetry and
    /// grows or drains slots between the min/max bounds below
    pub autoscale: bool,
    /// seconds between autoscaler policy evaluations
    pub scale_every_secs: u64,
    /// slot bounds for the autoscaler; 0 = derive (min 1, max 4x the
    /// configured count)
    pub min_actor_slots: usize,
    pub max_actor_slots: usize,
    pub min_inf_slots: usize,
    pub max_inf_slots: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            env: "rps".into(),
            n_agents: 1,
            learners_per_agent: 1,
            actors_per_learner: 2,
            envs_per_actor: 1,
            model_pools: 1,
            inf_servers: 0,
            game_mgr: "uniform".into(),
            algo: "ppo".into(),
            opponents_per_episode: 1,
            gamma: 0.99,
            publish_every: 4,
            period_steps: 50,
            total_steps: 200,
            replay_mode: "blocking".into(),
            seed: 0,
            hp_overrides: BTreeMap::new(),
            checkpoint_dir: None,
            checkpoint_every_secs: 30,
            checkpoint_keep: 3,
            pool_mem_budget_bytes: 0,
            resume: None,
            refresh_every: 1,
            infer_max_wait_us: 2_000,
            infer_refresh_ms: 50,
            mode: "thread".into(),
            controller_bind: "127.0.0.1:0".into(),
            advertise_host: None,
            heartbeat_ms: 1_000,
            heartbeat_timeout_ms: 5_000,
            stats_every_secs: 2,
            stats_jsonl: None,
            trace_sample: 0.0,
            trace_slow_ms: 50,
            fault_seed: 0,
            faults: None,
            chaos: None,
            local_lanes: "auto".into(),
            shm_dir: None,
            net_threads: 0,
            pool_replication: 2,
            autoscale: false,
            scale_every_secs: 5,
            min_actor_slots: 0,
            max_actor_slots: 0,
            min_inf_slots: 0,
            max_inf_slots: 0,
        }
    }
}

impl RunConfig {
    pub fn from_json(text: &str) -> Result<RunConfig> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = RunConfig::default();
        let get_num = |j: &Json, k: &str, d: f64| -> f64 {
            j.get(k).and_then(|v| v.as_f64()).unwrap_or(d)
        };
        if let Some(env) = j.get("env").and_then(|v| v.as_str()) {
            cfg.env = env.to_string();
        }
        cfg.n_agents = get_num(&j, "n_agents", cfg.n_agents as f64) as u32;
        cfg.learners_per_agent =
            get_num(&j, "learners_per_agent", cfg.learners_per_agent as f64) as usize;
        cfg.actors_per_learner =
            get_num(&j, "actors_per_learner", cfg.actors_per_learner as f64) as usize;
        cfg.envs_per_actor =
            get_num(&j, "envs_per_actor", cfg.envs_per_actor as f64) as usize;
        cfg.model_pools = get_num(&j, "model_pools", cfg.model_pools as f64) as usize;
        cfg.inf_servers = get_num(&j, "inf_servers", cfg.inf_servers as f64) as usize;
        if let Some(s) = j.get("game_mgr").and_then(|v| v.as_str()) {
            cfg.game_mgr = s.to_string();
        }
        if let Some(s) = j.get("algo").and_then(|v| v.as_str()) {
            cfg.algo = s.to_string();
        }
        cfg.opponents_per_episode = get_num(
            &j,
            "opponents_per_episode",
            cfg.opponents_per_episode as f64,
        ) as usize;
        cfg.gamma = get_num(&j, "gamma", cfg.gamma as f64) as f32;
        cfg.publish_every = get_num(&j, "publish_every", cfg.publish_every as f64) as u64;
        cfg.period_steps = get_num(&j, "period_steps", cfg.period_steps as f64) as u64;
        cfg.total_steps = get_num(&j, "total_steps", cfg.total_steps as f64) as u64;
        if let Some(s) = j.get("replay_mode").and_then(|v| v.as_str()) {
            cfg.replay_mode = s.to_string();
        }
        cfg.seed = get_num(&j, "seed", cfg.seed as f64) as u64;
        if let Some(s) = j.get("checkpoint_dir").and_then(|v| v.as_str()) {
            cfg.checkpoint_dir = Some(s.to_string());
        }
        cfg.checkpoint_every_secs = get_num(
            &j,
            "checkpoint_every_secs",
            cfg.checkpoint_every_secs as f64,
        ) as u64;
        cfg.checkpoint_keep =
            get_num(&j, "checkpoint_keep", cfg.checkpoint_keep as f64) as usize;
        // config files speak MB; the field is bytes so tests can be precise
        if let Some(mb) = j.get("pool_mem_budget_mb").and_then(|v| v.as_f64()) {
            cfg.pool_mem_budget_bytes = (mb * (1 << 20) as f64) as usize;
        }
        if let Some(s) = j.get("resume").and_then(|v| v.as_str()) {
            cfg.resume = Some(s.to_string());
        }
        cfg.refresh_every =
            get_num(&j, "refresh_every", cfg.refresh_every as f64) as u32;
        cfg.infer_max_wait_us =
            get_num(&j, "infer_max_wait_us", cfg.infer_max_wait_us as f64) as u64;
        cfg.infer_refresh_ms =
            get_num(&j, "infer_refresh_ms", cfg.infer_refresh_ms as f64) as u64;
        if let Some(s) = j.get("mode").and_then(|v| v.as_str()) {
            cfg.mode = s.to_string();
        }
        if let Some(s) = j.get("controller_bind").and_then(|v| v.as_str()) {
            cfg.controller_bind = s.to_string();
        }
        if let Some(s) = j.get("advertise_host").and_then(|v| v.as_str()) {
            cfg.advertise_host = Some(s.to_string());
        }
        cfg.heartbeat_ms = get_num(&j, "heartbeat_ms", cfg.heartbeat_ms as f64) as u64;
        cfg.heartbeat_timeout_ms = get_num(
            &j,
            "heartbeat_timeout_ms",
            cfg.heartbeat_timeout_ms as f64,
        ) as u64;
        cfg.stats_every_secs =
            get_num(&j, "stats_every_secs", cfg.stats_every_secs as f64) as u64;
        if let Some(s) = j.get("stats_jsonl").and_then(|v| v.as_str()) {
            cfg.stats_jsonl = Some(s.to_string());
        }
        cfg.trace_sample = get_num(&j, "trace_sample", cfg.trace_sample);
        cfg.trace_slow_ms =
            get_num(&j, "trace_slow_ms", cfg.trace_slow_ms as f64) as u64;
        cfg.fault_seed = get_num(&j, "fault_seed", cfg.fault_seed as f64) as u64;
        if let Some(s) = j.get("faults").and_then(|v| v.as_str()) {
            cfg.faults = Some(s.to_string());
        }
        if let Some(s) = j.get("chaos").and_then(|v| v.as_str()) {
            cfg.chaos = Some(s.to_string());
        }
        if let Some(s) = j.get("local_lanes").and_then(|v| v.as_str()) {
            cfg.local_lanes = s.to_string();
        }
        if let Some(s) = j.get("shm_dir").and_then(|v| v.as_str()) {
            cfg.shm_dir = Some(s.to_string());
        }
        cfg.net_threads = get_num(&j, "net_threads", cfg.net_threads as f64) as usize;
        cfg.pool_replication =
            get_num(&j, "pool_replication", cfg.pool_replication as f64) as usize;
        if let Some(b) = j.get("autoscale").and_then(|v| v.as_bool()) {
            cfg.autoscale = b;
        }
        cfg.scale_every_secs =
            get_num(&j, "scale_every_secs", cfg.scale_every_secs as f64) as u64;
        cfg.min_actor_slots =
            get_num(&j, "min_actor_slots", cfg.min_actor_slots as f64) as usize;
        cfg.max_actor_slots =
            get_num(&j, "max_actor_slots", cfg.max_actor_slots as f64) as usize;
        cfg.min_inf_slots =
            get_num(&j, "min_inf_slots", cfg.min_inf_slots as f64) as usize;
        cfg.max_inf_slots =
            get_num(&j, "max_inf_slots", cfg.max_inf_slots as f64) as usize;
        if let Some(obj) = j.get("hp").and_then(|v| v.as_obj()) {
            for (k, v) in obj {
                cfg.hp_overrides
                    .insert(k.clone(), v.as_f64().context("hp value")? as f32);
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path}"))?;
        Self::from_json(&text)
    }

    pub fn validate(&self) -> Result<()> {
        // the env spec must instantiate — catches unknown names and bad
        // `name:<param>` forms at startup instead of actor restart-churn
        crate::envs::make(&self.env, 0)
            .map(|_| ())
            .with_context(|| format!("invalid env spec '{}'", self.env))?;
        anyhow::ensure!(self.n_agents >= 1, "n_agents >= 1");
        anyhow::ensure!(self.learners_per_agent >= 1, "learners_per_agent >= 1");
        anyhow::ensure!(self.model_pools >= 1, "model_pools >= 1");
        anyhow::ensure!(
            matches!(self.algo.as_str(), "ppo" | "vtrace"),
            "algo must be ppo|vtrace"
        );
        // the full grammar, not just the prefix — "ratio:x2" silently
        // training with the default reuse count is the same bug class
        // as the numeric-CLI-flag fallback
        crate::learner::replay::ReplayMode::parse(&self.replay_mode)?;
        anyhow::ensure!(self.checkpoint_keep >= 1, "checkpoint_keep >= 1");
        anyhow::ensure!(self.envs_per_actor >= 1, "envs_per_actor >= 1");
        anyhow::ensure!(self.refresh_every >= 1, "refresh_every >= 1");
        anyhow::ensure!(self.infer_refresh_ms >= 1, "infer_refresh_ms >= 1");
        anyhow::ensure!(self.checkpoint_every_secs >= 1, "checkpoint_every_secs >= 1");
        anyhow::ensure!(
            matches!(self.mode.as_str(), "thread" | "procs"),
            "mode must be thread|procs"
        );
        anyhow::ensure!(self.heartbeat_ms >= 1, "heartbeat_ms >= 1");
        anyhow::ensure!(self.stats_every_secs >= 1, "stats_every_secs >= 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.trace_sample),
            "trace_sample must be in [0, 1]"
        );
        // a timeout tighter than two heartbeats would declare healthy
        // workers dead on ordinary scheduling jitter
        anyhow::ensure!(
            self.heartbeat_timeout_ms >= 2 * self.heartbeat_ms,
            "heartbeat_timeout_ms must be >= 2 * heartbeat_ms"
        );
        // a budget without a spill directory would silently never evict
        anyhow::ensure!(
            self.pool_mem_budget_bytes == 0
                || self.checkpoint_dir.is_some()
                || self.resume.is_some(),
            "pool_mem_budget_mb requires checkpoint_dir or resume (spill directory)"
        );
        // lane policy is a closed enum — a typo must not silently mean
        // "no lanes" (same bug class as the replay_mode prefix check)
        anyhow::ensure!(
            matches!(self.local_lanes.as_str(), "auto" | "on" | "off"),
            "local_lanes must be auto|on|off"
        );
        anyhow::ensure!(self.pool_replication >= 1, "pool_replication >= 1");
        anyhow::ensure!(self.scale_every_secs >= 1, "scale_every_secs >= 1");
        // the policy loop drives the controller's worker pool; thread
        // mode has no worker pool to grow into
        anyhow::ensure!(
            !self.autoscale || self.mode == "procs",
            "autoscale requires mode=procs (thread mode has no worker pool)"
        );
        anyhow::ensure!(
            self.max_actor_slots == 0
                || self.min_actor_slots <= self.max_actor_slots,
            "min_actor_slots must be <= max_actor_slots"
        );
        anyhow::ensure!(
            self.max_inf_slots == 0 || self.min_inf_slots <= self.max_inf_slots,
            "min_inf_slots must be <= max_inf_slots"
        );
        // a misspelled fault spec must fail the launch, not silently
        // run the drill with zero injection
        if let Some(spec) = &self.faults {
            crate::transport::fault::parse_spec(spec)
                .with_context(|| format!("invalid faults spec '{spec}'"))?;
        }
        if let Some(spec) = &self.chaos {
            let events = crate::orchestrator::chaos::parse_chaos(spec)
                .with_context(|| format!("invalid chaos spec '{spec}'"))?;
            anyhow::ensure!(
                self.mode == "procs",
                "chaos schedules require mode=procs (threads cannot be SIGKILLed)"
            );
            if events.iter().any(|e| e.role == "controller") {
                // a controller restart must resume from a snapshot and
                // come back on the address the workers already know
                anyhow::ensure!(
                    self.checkpoint_dir.is_some(),
                    "kill:controller requires checkpoint_dir (restart resumes from snapshot)"
                );
                anyhow::ensure!(
                    !self.controller_bind.ends_with(":0"),
                    "kill:controller requires a fixed controller_bind port (workers must be able to re-register)"
                );
            }
            if events.iter().any(|e| e.role == "pool") {
                anyhow::ensure!(
                    self.model_pools >= 2,
                    "kill:pool requires model_pools >= 2 (a surviving replica)"
                );
            }
        }
        Ok(())
    }

    pub fn replay_mode(&self) -> crate::learner::replay::ReplayMode {
        // validate() enforces the grammar before any run launches
        crate::learner::replay::ReplayMode::parse(&self.replay_mode)
            .expect("replay_mode was validated")
    }

    /// The worker-facing slice of this config: everything a role worker
    /// needs, handed out by the controller with each assignment.
    pub fn slice(&self) -> crate::proto::RunSlice {
        crate::proto::RunSlice {
            env: self.env.clone(),
            algo: self.algo.clone(),
            replay_mode: self.replay_mode.clone(),
            seed: self.seed,
            gamma: self.gamma,
            total_steps: self.total_steps,
            period_steps: self.period_steps,
            publish_every: self.publish_every,
            learners_per_agent: self.learners_per_agent as u32,
            envs_per_actor: self.envs_per_actor as u32,
            refresh_every: self.refresh_every,
            infer_max_wait_us: self.infer_max_wait_us,
            infer_refresh_ms: self.infer_refresh_ms,
            heartbeat_ms: self.heartbeat_ms,
            trace_sample: self.trace_sample,
            trace_slow_ms: self.trace_slow_ms,
            fault_seed: self.fault_seed,
            fault_spec: self.faults.clone().unwrap_or_default(),
            local_lanes: self.local_lanes.clone(),
            shm_dir: self.shm_dir.clone().unwrap_or_default(),
            net_threads: self.net_threads as u32,
            pool_replication: self.effective_replication() as u32,
        }
    }

    /// Replication factor after clamping to the replica count — what
    /// every process (deployment and workers alike) must install before
    /// building pool clients, so all rings agree.
    pub fn effective_replication(&self) -> usize {
        self.pool_replication.max(1).min(self.model_pools.max(1))
    }

    /// Opponents per episode implied by the env if not set explicitly.
    /// `validate()` guarantees the spec parameter parses, so the
    /// fallbacks here are unreachable on a validated config.
    pub fn effective_opponents(&self) -> usize {
        if self.opponents_per_episode > 0 {
            return self.opponents_per_episode;
        }
        let (base, param) = crate::envs::spec(&self.env);
        match base {
            // doom_lite:<players> = (players - 1) single-slot opponents
            "doom_lite" => param
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or(8)
                .saturating_sub(1)
                .max(1),
            "pommerman_ffa" => 3,
            _ => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let cfg = RunConfig::from_json(
            r#"{
            "env": "pommerman", "n_agents": 2, "learners_per_agent": 2,
            "actors_per_learner": 4, "model_pools": 2, "inf_servers": 1,
            "game_mgr": "sp_pfsp", "algo": "ppo", "gamma": 0.995,
            "publish_every": 8, "period_steps": 100, "total_steps": 1000,
            "replay_mode": "ratio:3", "seed": 7,
            "hp": {"lr": 0.001, "ent_coef": 0.02}
        }"#,
        )
        .unwrap();
        assert_eq!(cfg.env, "pommerman");
        assert_eq!(cfg.learners_per_agent, 2);
        assert_eq!(cfg.hp_overrides["lr"], 0.001);
        assert!(matches!(
            cfg.replay_mode(),
            crate::learner::replay::ReplayMode::Ratio { max_reuse: 3 }
        ));
    }

    #[test]
    fn defaults_fill_missing() {
        let cfg = RunConfig::from_json(r#"{"env": "rps"}"#).unwrap();
        assert_eq!(cfg.actors_per_learner, 2);
        assert_eq!(cfg.algo, "ppo");
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RunConfig::from_json(r#"{"algo": "dqn"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"replay_mode": "nope"}"#).is_err());
        // a malformed ratio count must error, not fall back silently
        assert!(RunConfig::from_json(r#"{"replay_mode": "ratio:x2"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"replay_mode": "ratio:0"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"replay_mode": "ratio:3"}"#).is_ok());
        assert!(RunConfig::from_json(r#"{"n_agents": 0}"#).is_err());
        // env specs fail fast at validation, not at actor spawn
        assert!(RunConfig::from_json(r#"{"env": "nope"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"env": "doom_lite:20"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"env": "doom_lite:x"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"env": "doom_lite:4"}"#).is_ok());
    }

    #[test]
    fn checkpoint_fields_parse() {
        let cfg = RunConfig::from_json(
            r#"{
            "env": "rps", "checkpoint_dir": "/tmp/league-ckpt",
            "checkpoint_every_secs": 5, "checkpoint_keep": 2,
            "pool_mem_budget_mb": 0.5, "resume": "/tmp/league-ckpt"
        }"#,
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_dir.as_deref(), Some("/tmp/league-ckpt"));
        assert_eq!(cfg.checkpoint_every_secs, 5);
        assert_eq!(cfg.checkpoint_keep, 2);
        assert_eq!(cfg.pool_mem_budget_bytes, 512 * 1024);
        assert_eq!(cfg.resume.as_deref(), Some("/tmp/league-ckpt"));
        // defaults: no durability, no budget
        let d = RunConfig::default();
        assert!(d.checkpoint_dir.is_none() && d.resume.is_none());
        assert_eq!(d.pool_mem_budget_bytes, 0);
        assert!(RunConfig::from_json(r#"{"checkpoint_keep": 0}"#).is_err());
        // a budget with nowhere to spill must be rejected, not ignored
        assert!(RunConfig::from_json(r#"{"pool_mem_budget_mb": 64}"#).is_err());
    }

    #[test]
    fn data_plane_knobs_parse() {
        let cfg = RunConfig::from_json(
            r#"{
            "env": "rps", "refresh_every": 4,
            "infer_max_wait_us": 500, "infer_refresh_ms": 20
        }"#,
        )
        .unwrap();
        assert_eq!(cfg.refresh_every, 4);
        assert_eq!(cfg.infer_max_wait_us, 500);
        assert_eq!(cfg.infer_refresh_ms, 20);
        let d = RunConfig::default();
        assert_eq!(d.refresh_every, 1);
        assert_eq!(d.infer_max_wait_us, 2_000);
        assert_eq!(d.infer_refresh_ms, 50);
        assert!(RunConfig::from_json(r#"{"refresh_every": 0}"#).is_err());
        assert!(RunConfig::from_json(r#"{"infer_refresh_ms": 0}"#).is_err());
    }

    #[test]
    fn deployment_mode_parses_and_validates() {
        let cfg = RunConfig::from_json(
            r#"{
            "env": "rps", "mode": "procs",
            "controller_bind": "0.0.0.0:9100",
            "advertise_host": "league.internal",
            "heartbeat_ms": 200, "heartbeat_timeout_ms": 900
        }"#,
        )
        .unwrap();
        assert_eq!(cfg.mode, "procs");
        assert_eq!(cfg.controller_bind, "0.0.0.0:9100");
        assert_eq!(cfg.advertise_host.as_deref(), Some("league.internal"));
        assert_eq!(cfg.heartbeat_ms, 200);
        assert_eq!(cfg.heartbeat_timeout_ms, 900);
        let d = RunConfig::default();
        assert_eq!(d.mode, "thread");
        assert_eq!(d.heartbeat_ms, 1_000);
        assert_eq!(d.heartbeat_timeout_ms, 5_000);
        assert!(RunConfig::from_json(r#"{"mode": "kubernetes"}"#).is_err());
        // timeouts tighter than two heartbeats are a foot-gun
        assert!(RunConfig::from_json(
            r#"{"heartbeat_ms": 1000, "heartbeat_timeout_ms": 1500}"#
        )
        .is_err());
        // the worker slice mirrors the config
        let s = cfg.slice();
        assert_eq!(s.env, "rps");
        assert_eq!(s.heartbeat_ms, 200);
        assert_eq!(s.learners_per_agent, 1);
    }

    #[test]
    fn telemetry_knobs_parse() {
        let cfg = RunConfig::from_json(
            r#"{
            "env": "rps", "stats_every_secs": 5,
            "stats_jsonl": "/tmp/league-stats.jsonl"
        }"#,
        )
        .unwrap();
        assert_eq!(cfg.stats_every_secs, 5);
        assert_eq!(cfg.stats_jsonl.as_deref(), Some("/tmp/league-stats.jsonl"));
        let d = RunConfig::default();
        assert_eq!(d.stats_every_secs, 2);
        assert!(d.stats_jsonl.is_none());
        assert!(RunConfig::from_json(r#"{"stats_every_secs": 0}"#).is_err());
    }

    #[test]
    fn trace_knobs_parse_and_ride_the_slice() {
        let cfg = RunConfig::from_json(
            r#"{"env": "rps", "trace_sample": 0.25, "trace_slow_ms": 10}"#,
        )
        .unwrap();
        assert_eq!(cfg.trace_sample, 0.25);
        assert_eq!(cfg.trace_slow_ms, 10);
        let s = cfg.slice();
        assert_eq!(s.trace_sample, 0.25);
        assert_eq!(s.trace_slow_ms, 10);
        let d = RunConfig::default();
        assert_eq!(d.trace_sample, 0.0);
        assert_eq!(d.trace_slow_ms, 50);
        assert!(RunConfig::from_json(r#"{"trace_sample": 1.5}"#).is_err());
        assert!(RunConfig::from_json(r#"{"trace_sample": -0.1}"#).is_err());
    }

    #[test]
    fn fault_and_chaos_knobs_parse_and_validate() {
        let cfg = RunConfig::from_json(
            r#"{
            "env": "rps", "mode": "procs", "model_pools": 2,
            "fault_seed": 7, "faults": "drop:learner@0.1, delay:*@0.05+3",
            "chaos": "kill:inf-server@500,kill:pool@900"
        }"#,
        )
        .unwrap();
        assert_eq!(cfg.fault_seed, 7);
        assert_eq!(
            cfg.faults.as_deref(),
            Some("drop:learner@0.1, delay:*@0.05+3")
        );
        assert_eq!(cfg.chaos.as_deref(), Some("kill:inf-server@500,kill:pool@900"));
        // the fault plan rides the worker slice so every process in a
        // procs run derives the same seeded schedule
        let s = cfg.slice();
        assert_eq!(s.fault_seed, 7);
        assert_eq!(s.fault_spec, "drop:learner@0.1, delay:*@0.05+3");
        let d = RunConfig::default();
        assert_eq!(d.fault_seed, 0);
        assert!(d.faults.is_none() && d.chaos.is_none());
        assert!(d.slice().fault_spec.is_empty());
        // bad grammar fails the launch instead of running faultless
        assert!(RunConfig::from_json(r#"{"faults": "explode:*@0.5"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"faults": "drop:@0.5"}"#).is_err());
        assert!(
            RunConfig::from_json(r#"{"mode": "procs", "chaos": "kill:ghost@10"}"#)
                .is_err()
        );
        // chaos needs real processes to kill
        assert!(RunConfig::from_json(r#"{"chaos": "kill:actor@100"}"#).is_err());
        // a controller kill without a snapshot dir or fixed port cannot recover
        assert!(RunConfig::from_json(
            r#"{"mode": "procs", "controller_bind": "127.0.0.1:9111",
                "chaos": "kill:controller@100"}"#
        )
        .is_err());
        assert!(RunConfig::from_json(
            r#"{"mode": "procs", "checkpoint_dir": "/tmp/ck",
                "chaos": "kill:controller@100"}"#
        )
        .is_err());
        assert!(RunConfig::from_json(
            r#"{"mode": "procs", "checkpoint_dir": "/tmp/ck",
                "controller_bind": "127.0.0.1:9111",
                "chaos": "kill:controller@100"}"#
        )
        .is_ok());
        // killing the only pool replica would lose every model
        assert!(
            RunConfig::from_json(r#"{"mode": "procs", "chaos": "kill:pool@100"}"#)
                .is_err()
        );
    }

    #[test]
    fn transport_knobs_parse_and_ride_the_slice() {
        let cfg = RunConfig::from_json(
            r#"{
            "env": "rps", "local_lanes": "on",
            "shm_dir": "/tmp/lanes", "net_threads": 3
        }"#,
        )
        .unwrap();
        assert_eq!(cfg.local_lanes, "on");
        assert_eq!(cfg.shm_dir.as_deref(), Some("/tmp/lanes"));
        assert_eq!(cfg.net_threads, 3);
        let s = cfg.slice();
        assert_eq!(s.local_lanes, "on");
        assert_eq!(s.shm_dir, "/tmp/lanes");
        assert_eq!(s.net_threads, 3);
        let d = RunConfig::default();
        assert_eq!(d.local_lanes, "auto");
        assert!(d.shm_dir.is_none());
        assert_eq!(d.net_threads, 0);
        assert!(d.slice().shm_dir.is_empty());
        // a lane-policy typo must fail the launch, not silently mean off
        assert!(RunConfig::from_json(r#"{"local_lanes": "yes"}"#).is_err());
    }

    #[test]
    fn elasticity_knobs_parse_and_validate() {
        let cfg = RunConfig::from_json(
            r#"{
            "env": "rps", "mode": "procs", "model_pools": 3,
            "pool_replication": 2, "autoscale": true, "scale_every_secs": 2,
            "min_actor_slots": 1, "max_actor_slots": 8,
            "min_inf_slots": 1, "max_inf_slots": 4
        }"#,
        )
        .unwrap();
        assert_eq!(cfg.pool_replication, 2);
        assert!(cfg.autoscale);
        assert_eq!(cfg.scale_every_secs, 2);
        assert_eq!((cfg.min_actor_slots, cfg.max_actor_slots), (1, 8));
        assert_eq!((cfg.min_inf_slots, cfg.max_inf_slots), (1, 4));
        assert_eq!(cfg.effective_replication(), 2);
        // the slice carries the clamped R so workers build the same ring
        assert_eq!(cfg.slice().pool_replication, 2);
        let d = RunConfig::default();
        assert_eq!(d.pool_replication, 2);
        assert!(!d.autoscale);
        assert_eq!(d.scale_every_secs, 5);
        // single replica clamps R to 1 — the unsharded seed behaviour
        assert_eq!(d.effective_replication(), 1);
        assert!(RunConfig::from_json(r#"{"pool_replication": 0}"#).is_err());
        assert!(RunConfig::from_json(r#"{"scale_every_secs": 0}"#).is_err());
        // autoscale needs a worker pool to grow into
        assert!(RunConfig::from_json(r#"{"autoscale": true}"#).is_err());
        assert!(RunConfig::from_json(
            r#"{"mode": "procs", "autoscale": true,
                "min_actor_slots": 5, "max_actor_slots": 2}"#
        )
        .is_err());
    }

    #[test]
    fn env_implies_opponents() {
        let mut cfg = RunConfig::default();
        cfg.opponents_per_episode = 0;
        cfg.env = "doom_lite".into();
        assert_eq!(cfg.effective_opponents(), 7);
        // parameterized specs imply their own opponent count
        cfg.env = "doom_lite:4".into();
        assert_eq!(cfg.effective_opponents(), 3);
        cfg.env = "synthetic:64".into();
        assert_eq!(cfg.effective_opponents(), 1);
    }

    #[test]
    fn envs_per_actor_parses_and_validates() {
        let cfg = RunConfig::from_json(
            r#"{"env": "synthetic:64", "envs_per_actor": 8}"#,
        )
        .unwrap();
        assert_eq!(cfg.envs_per_actor, 8);
        assert_eq!(cfg.env, "synthetic:64");
        assert_eq!(RunConfig::default().envs_per_actor, 1);
        assert!(RunConfig::from_json(r#"{"envs_per_actor": 0}"#).is_err());
    }
}
