// Seeded-bad fixture: TAG_B is declared and encoded but Msg::decode
// has no arm for it — a silent "unknown msg tag" at runtime.
// lint: proto-registry
pub const TAG_A: u8 = 1;
pub const TAG_B: u8 = 2;

impl Wire for Msg {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Msg::A => buf.put_u8(TAG_A),
            Msg::B => buf.put_u8(TAG_B),
        }
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        let tag = cur.u8()?;
        Ok(match tag {
            TAG_A => Msg::A,
            t => bail!("unknown tag {t}"),
        })
    }
}
