//! Payoff matrix + Elo ratings over the model pool.
//!
//! The GameMgr (paper §3.2) "maintains a payoff matrix for all the
//! models stored in the pool M".  Outcomes are 1 / 0.5 / 0 from the
//! row player's perspective; win-rates use a weak uniform prior so
//! fresh pairs aren't treated as certainly-even or certainly-lost.

use crate::proto::ModelKey;
use crate::util::codec::{Cursor, Enc, Wire};
use anyhow::Result;
use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug, Default)]
pub struct PairStats {
    pub games: u32,
    /// sum of outcomes (win=1, tie=0.5) for the row player
    pub score: f64,
}

#[derive(Clone, Default)]
pub struct PayoffMatrix {
    pairs: BTreeMap<(ModelKey, ModelKey), PairStats>,
    elo: BTreeMap<ModelKey, f64>,
    pub elo_k: f64,
}

pub const ELO_BASE: f64 = 1200.0;

impl PayoffMatrix {
    pub fn new() -> Self {
        PayoffMatrix { pairs: BTreeMap::new(), elo: BTreeMap::new(), elo_k: 16.0 }
    }

    pub fn add_model(&mut self, key: ModelKey) {
        self.elo.entry(key).or_insert(ELO_BASE);
    }

    pub fn models(&self) -> Vec<ModelKey> {
        self.elo.keys().copied().collect()
    }

    /// Record `outcome` (row player's view) for row vs col.
    pub fn record(&mut self, row: ModelKey, col: ModelKey, outcome: f32) {
        let e = self.pairs.entry((row, col)).or_default();
        e.games += 1;
        e.score += outcome as f64;
        // mirrored entry keeps lookups one-sided
        let m = self.pairs.entry((col, row)).or_default();
        m.games += 1;
        m.score += 1.0 - outcome as f64;
        // Elo update
        let ra = *self.elo.entry(row).or_insert(ELO_BASE);
        let rb = *self.elo.entry(col).or_insert(ELO_BASE);
        let expect = 1.0 / (1.0 + 10f64.powf((rb - ra) / 400.0));
        let delta = self.elo_k * (outcome as f64 - expect);
        *self.elo.get_mut(&row).unwrap() += delta;
        *self.elo.get_mut(&col).unwrap() -= delta;
    }

    pub fn stats(&self, row: ModelKey, col: ModelKey) -> PairStats {
        self.pairs.get(&(row, col)).copied().unwrap_or_default()
    }

    /// Win-rate of `row` against `col` with a uniform(1 game, 0.5) prior.
    pub fn winrate(&self, row: ModelKey, col: ModelKey) -> f64 {
        let s = self.stats(row, col);
        (s.score + 0.5) / (s.games as f64 + 1.0)
    }

    /// Aggregate win-rate of `key` against the whole pool.
    pub fn pool_winrate(&self, key: ModelKey) -> f64 {
        let mut score = 0.0;
        let mut games = 0u32;
        for (&(r, _c), s) in self.pairs.range((key, ModelKey::new(0, 0))..) {
            if r != key {
                break;
            }
            score += s.score;
            games += s.games;
        }
        (score + 0.5) / (games as f64 + 1.0)
    }

    pub fn elo(&self, key: ModelKey) -> f64 {
        self.elo.get(&key).copied().unwrap_or(ELO_BASE)
    }

    pub fn total_games(&self) -> u64 {
        // each match recorded twice (mirror)
        self.pairs.values().map(|s| s.games as u64).sum::<u64>() / 2
    }
}

/// Snapshot codec: BTreeMap iteration is ordered, so encoding the same
/// matrix twice yields identical bytes (bit-exact checkpoint round-trips).
impl Wire for PayoffMatrix {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_f64(self.elo_k);
        buf.put_u32(self.elo.len() as u32);
        for (key, rating) in &self.elo {
            key.encode(buf);
            buf.put_f64(*rating);
        }
        buf.put_u32(self.pairs.len() as u32);
        for ((row, col), s) in &self.pairs {
            row.encode(buf);
            col.encode(buf);
            buf.put_u32(s.games);
            buf.put_f64(s.score);
        }
    }

    fn decode(cur: &mut Cursor) -> Result<Self> {
        let elo_k = cur.f64()?;
        let n_elo = cur.u32()? as usize;
        let mut elo = BTreeMap::new();
        for _ in 0..n_elo {
            let key = ModelKey::decode(cur)?;
            let rating = cur.f64()?;
            elo.insert(key, rating);
        }
        let n_pairs = cur.u32()? as usize;
        let mut pairs = BTreeMap::new();
        for _ in 0..n_pairs {
            let row = ModelKey::decode(cur)?;
            let col = ModelKey::decode(cur)?;
            let games = cur.u32()?;
            let score = cur.f64()?;
            pairs.insert((row, col), PairStats { games, score });
        }
        Ok(PayoffMatrix { pairs, elo, elo_k })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(v: u32) -> ModelKey {
        ModelKey::new(0, v)
    }

    #[test]
    fn record_mirrors() {
        let mut p = PayoffMatrix::new();
        p.record(k(1), k(2), 1.0);
        p.record(k(1), k(2), 0.0);
        p.record(k(1), k(2), 1.0);
        let s = p.stats(k(1), k(2));
        assert_eq!(s.games, 3);
        assert_eq!(s.score, 2.0);
        let m = p.stats(k(2), k(1));
        assert_eq!(m.games, 3);
        assert_eq!(m.score, 1.0);
    }

    #[test]
    fn winrate_prior_pulls_to_half() {
        let p = PayoffMatrix::new();
        assert_eq!(p.winrate(k(1), k(2)), 0.5);
        let mut p = PayoffMatrix::new();
        p.record(k(1), k(2), 1.0);
        let w = p.winrate(k(1), k(2));
        assert!(w > 0.5 && w < 1.0, "{w}");
    }

    #[test]
    fn elo_moves_toward_winner() {
        let mut p = PayoffMatrix::new();
        p.add_model(k(1));
        p.add_model(k(2));
        for _ in 0..20 {
            p.record(k(1), k(2), 1.0);
        }
        assert!(p.elo(k(1)) > p.elo(k(2)) + 100.0);
        // zero-sum: total Elo conserved
        assert!((p.elo(k(1)) + p.elo(k(2)) - 2.0 * ELO_BASE).abs() < 1e-9);
    }

    #[test]
    fn record_is_mirror_symmetric_under_random_play() {
        // for every pair, row score + col score == games on both sides
        let mut p = PayoffMatrix::new();
        let mut rng = crate::util::rng::Pcg32::new(31, 7);
        for _ in 0..500 {
            let row = k(rng.below(5));
            let col = k(rng.below(5));
            let outcome = *rng.choose(&[0.0f32, 0.5, 1.0]);
            p.record(row, col, outcome);
        }
        for a in 0..5 {
            for b in 0..5 {
                let s = p.stats(k(a), k(b));
                let m = p.stats(k(b), k(a));
                assert_eq!(s.games, m.games, "{a} vs {b} game counts");
                assert!(
                    (s.score + m.score - s.games as f64).abs() < 1e-9,
                    "{a} vs {b}: {} + {} != {}",
                    s.score,
                    m.score,
                    s.games
                );
            }
        }
    }

    #[test]
    fn elo_is_zero_sum_under_record() {
        let mut p = PayoffMatrix::new();
        for v in 0..6 {
            p.add_model(k(v));
        }
        let mut rng = crate::util::rng::Pcg32::new(13, 5);
        for _ in 0..400 {
            let row = k(rng.below(6));
            let col = k(rng.below(6));
            p.record(row, col, *rng.choose(&[0.0f32, 0.5, 1.0]));
        }
        let total: f64 = (0..6).map(|v| p.elo(k(v))).sum();
        assert!(
            (total - 6.0 * ELO_BASE).abs() < 1e-6,
            "Elo not conserved: {total}"
        );
    }

    #[test]
    fn pool_winrate_fresh_pair_uses_prior() {
        // a model with no recorded games sits exactly at the 0.5 prior
        let mut p = PayoffMatrix::new();
        p.add_model(k(1));
        assert_eq!(p.pool_winrate(k(1)), 0.5);
        assert_eq!(p.pool_winrate(k(99)), 0.5, "unknown key also gets the prior");
        // one win pulls above 0.5 but stays below certainty
        p.record(k(1), k(2), 1.0);
        let w = p.pool_winrate(k(1));
        assert!(w > 0.5 && w < 1.0, "{w}");
    }

    #[test]
    fn wire_roundtrip_is_bit_exact() {
        let mut p = PayoffMatrix::new();
        let mut rng = crate::util::rng::Pcg32::new(77, 2);
        for _ in 0..200 {
            p.record(k(rng.below(4)), k(rng.below(4)), rng.next_f32());
        }
        let bytes = p.to_bytes();
        let back = PayoffMatrix::from_bytes(&bytes).unwrap();
        assert_eq!(bytes, back.to_bytes(), "re-encode must be identical");
        for a in 0..4 {
            assert_eq!(p.elo(k(a)).to_bits(), back.elo(k(a)).to_bits());
            for b in 0..4 {
                assert_eq!(
                    p.winrate(k(a), k(b)).to_bits(),
                    back.winrate(k(a), k(b)).to_bits()
                );
            }
        }
    }

    #[test]
    fn pool_winrate_aggregates() {
        let mut p = PayoffMatrix::new();
        p.record(k(1), k(2), 1.0);
        p.record(k(1), k(3), 1.0);
        p.record(k(1), k(4), 0.0);
        let w = p.pool_winrate(k(1));
        assert!((w - (2.0 + 0.5) / 4.0).abs() < 1e-9, "{w}");
        assert_eq!(p.total_games(), 3);
    }
}
