//! Consistent-hash placement for the sharded, replicated ModelPool.
//!
//! Every agent's models live on `R` of the `N` replica slots, chosen by
//! walking a 128-vnode-per-slot hash ring.  Two properties carry the
//! elastic-league design:
//!
//! * **Index-keyed vnodes.**  Ring points hash the replica *slot index*,
//!   not its address, so the controller, the snapshotter, and every
//!   worker derive the identical placement from the same [`ShardMap`] —
//!   address rewriting (`--advertise-host`) cannot split the ring.
//! * **Tombstones, not compaction.**  A retired replica leaves an empty
//!   string in `ShardMap::replicas`; the survivors keep their slot
//!   indices and therefore their ring points.  Removing one replica
//!   moves exactly the victim's keys (~1/N), and a surviving owner of a
//!   key is still an owner afterwards — which is why reads keep
//!   succeeding during `kill:pool` failover even on clients holding the
//!   stale map.

use crate::proto::ShardMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

/// Ring points per live replica slot: enough that primary-owner load is
/// balanced within ~25% up to 16 replicas (verified by the property
/// tests below); lookup stays one binary search over N*128 points.
pub const VNODES: usize = 128;

/// Process-wide default replication factor, installed from the run
/// config (`RunConfig::pool_replication` / `RunSlice::pool_replication`)
/// before any `ModelPoolClient` is built — avoids threading R through
/// every role constructor.  Effective R is always clamped to the live
/// replica count.
static DEFAULT_REPLICATION: AtomicUsize = AtomicUsize::new(2);

pub fn set_default_replication(r: usize) {
    DEFAULT_REPLICATION.store(r.max(1), Ordering::Relaxed);
}

pub fn default_replication() -> usize {
    DEFAULT_REPLICATION.load(Ordering::Relaxed).max(1)
}

/// splitmix64 finalizer: cheap, deterministic, and well-distributed —
/// the same arithmetic on every process is the whole point.
fn mix(z: u64) -> u64 {
    let z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    let z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

fn vnode_point(slot: u32, vnode: usize) -> u64 {
    mix((((slot as u64) + 1) << 32) | vnode as u64)
}

fn key_point(agent: u32) -> u64 {
    // different domain from vnode points (low 32 bits of the pre-mix
    // input) so key and vnode streams never collide systematically
    mix(agent as u64 ^ 0xd1b5_4a32_d192_ed03)
}

/// The derived lookup structure for one [`ShardMap`] version: sorted
/// `(point, slot)` ring + the effective replication factor.  Build once
/// per map install, share via `Arc`.
#[derive(Debug)]
pub struct Ring {
    points: Vec<(u64, u32)>,
    replication: usize,
    live: usize,
}

impl Ring {
    pub fn build(map: &ShardMap) -> Ring {
        let live = map.live();
        let mut points = Vec::with_capacity(live.len() * VNODES);
        for &slot in &live {
            for j in 0..VNODES {
                points.push((vnode_point(slot, j), slot));
            }
        }
        points.sort_unstable();
        Ring {
            points,
            replication: (map.replication as usize).max(1).min(live.len().max(1)),
            live: live.len(),
        }
    }

    pub fn live(&self) -> usize {
        self.live
    }

    pub fn replication(&self) -> usize {
        self.replication
    }

    /// The R distinct replica slots owning `agent`, primary first:
    /// clockwise walk from the key's ring point.  Empty ring (a map not
    /// yet installed) owns nothing — callers treat that as "serve
    /// everything" so a replica never bounces traffic before the
    /// controller publishes the bootstrap map.
    pub fn owners(&self, agent: u32) -> Vec<u32> {
        if self.points.is_empty() {
            return Vec::new();
        }
        let kp = key_point(agent);
        let start = self.points.partition_point(|&(p, _)| p < kp);
        let n = self.points.len();
        let mut out: Vec<u32> = Vec::with_capacity(self.replication);
        for k in 0..n {
            let slot = self.points[(start + k) % n].1;
            if !out.contains(&slot) {
                out.push(slot);
                if out.len() == self.replication {
                    break;
                }
            }
        }
        out
    }

    pub fn primary(&self, agent: u32) -> Option<u32> {
        self.owners(agent).first().copied()
    }

    /// Whether `slot` is one of the owners of `agent`.  An empty ring
    /// (pre-bootstrap) answers true: serve rather than bounce.
    pub fn is_owner(&self, agent: u32, slot: u32) -> bool {
        if self.points.is_empty() {
            return true;
        }
        self.owners(agent).contains(&slot)
    }
}

/// The shared, versioned (map, ring) pair: one per pool deployment,
/// `Arc`-cloned into every in-process replica server and the
/// controller.  `install` only accepts strictly newer maps, so a stale
/// gossip can never roll placement back.
pub struct MapHolder {
    inner: RwLock<(Arc<ShardMap>, Arc<Ring>)>,
}

impl MapHolder {
    pub fn new(map: ShardMap) -> MapHolder {
        let ring = Arc::new(Ring::build(&map));
        MapHolder { inner: RwLock::new((Arc::new(map), ring)) }
    }

    /// Current (map, ring); cheap Arc clones.
    pub fn get(&self) -> (Arc<ShardMap>, Arc<Ring>) {
        self.inner.read().unwrap().clone()
    }

    pub fn version(&self) -> u64 {
        self.inner.read().unwrap().0.version
    }

    /// Swap in the real replica addresses once ephemeral ports are
    /// known, keeping the version.  Placement is index-keyed, so the
    /// ring is identical as long as the live pattern matches — the
    /// launcher seeds the holder with placeholder addresses (the pool
    /// servers need it at bind time), then fixes the addresses here.
    /// Workers derive the same v1 map from the assignment's address
    /// list, so no version bump is needed or wanted.
    pub fn set_addrs(&self, addrs: Vec<String>) {
        let mut g = self.inner.write().unwrap();
        debug_assert_eq!(g.0.replicas.len(), addrs.len());
        let mut map = (*g.0).clone();
        map.replicas = addrs;
        let ring = Arc::new(Ring::build(&map));
        *g = (Arc::new(map), ring);
    }

    /// Install `map` iff it is newer than what we hold.  Returns true
    /// when installed.
    pub fn install(&self, map: ShardMap) -> bool {
        let mut g = self.inner.write().unwrap();
        if map.version <= g.0.version {
            return false;
        }
        let ring = Arc::new(Ring::build(&map));
        *g = (Arc::new(map), ring);
        true
    }
}

/// The version-1 map every process derives independently from the
/// replica address list + replication factor of its run config: same
/// inputs, same map, no bootstrap round-trip.
pub fn bootstrap_map(addrs: &[String], replication: u32) -> ShardMap {
    ShardMap {
        version: 1,
        replicas: addrs.to_vec(),
        replication: replication.max(1).min(addrs.len().max(1) as u32),
    }
}

/// `map` with slot `victim` tombstoned and the version bumped — the
/// membership change published on `kill:pool` failover.
pub fn without_replica(map: &ShardMap, victim: u32) -> ShardMap {
    let mut next = map.clone();
    if let Some(slot) = next.replicas.get_mut(victim as usize) {
        slot.clear();
    }
    next.version += 1;
    next
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map_of(n: usize, r: u32) -> ShardMap {
        bootstrap_map(
            &(0..n).map(|i| format!("10.0.0.{i}:9001")).collect::<Vec<_>>(),
            r,
        )
    }

    /// Satellite: primary-owner placement is balanced within 25% of the
    /// fair share for every fleet size we deploy (2..=16 replicas).
    #[test]
    fn placement_balanced_within_25_percent() {
        const AGENTS: u32 = 4096;
        for n in 2..=16usize {
            let ring = Ring::build(&map_of(n, 1));
            let mut counts = vec![0u32; n];
            for a in 0..AGENTS {
                counts[ring.primary(a).unwrap() as usize] += 1;
            }
            let fair = AGENTS as f64 / n as f64;
            for (slot, &c) in counts.iter().enumerate() {
                let load = c as f64 / fair;
                assert!(
                    (0.75..=1.25).contains(&load),
                    "N={n} slot {slot}: load {load:.3}x fair share (counts {counts:?})"
                );
            }
        }
    }

    /// Satellite: adding one replica moves only ~1/N of the keys, and
    /// every moved key moves TO the new replica (nothing reshuffles
    /// between survivors).
    #[test]
    fn adding_replica_moves_about_one_nth() {
        const KEYS: u32 = 8192;
        let r6 = Ring::build(&map_of(6, 1));
        let r7 = Ring::build(&map_of(7, 1));
        let mut moved = 0u32;
        for a in 0..KEYS {
            let (p6, p7) = (r6.primary(a).unwrap(), r7.primary(a).unwrap());
            if p6 != p7 {
                moved += 1;
                assert_eq!(p7, 6, "key {a} moved to survivor {p7}, not the new replica");
            }
        }
        let frac = moved as f64 / KEYS as f64;
        // fair share is 1/7 ≈ 0.143; allow [0.5x, 2x]
        assert!(
            (0.071..=0.286).contains(&frac),
            "moved {frac:.4} of keys on add (want ~1/7)"
        );
    }

    /// Satellite: tombstoning one replica moves exactly the victim's
    /// keys — survivors' placements are untouched, so a rebalance only
    /// transfers the blobs that actually changed hands.
    #[test]
    fn removing_replica_moves_only_victims_keys() {
        const KEYS: u32 = 8192;
        let full = map_of(6, 1);
        let r6 = Ring::build(&full);
        let r5 = Ring::build(&without_replica(&full, 2));
        let (mut moved, mut was_victims) = (0u32, 0u32);
        for a in 0..KEYS {
            let p6 = r6.primary(a).unwrap();
            if p6 == 2 {
                was_victims += 1;
            }
            if p6 != r5.primary(a).unwrap() {
                moved += 1;
                assert_eq!(p6, 2, "key {a} moved but was not owned by the victim");
            }
        }
        assert_eq!(moved, was_victims, "survivor placements must be untouched");
        assert!(moved > 0, "victim owned no keys — ring degenerate");
    }

    /// The failover invariant `kill:pool` relies on: with R >= 2, every
    /// surviving old owner of a key is still an owner under the
    /// tombstoned map, so clients holding the stale map keep reading
    /// from a live owner.
    #[test]
    fn surviving_owners_remain_owners_after_tombstone() {
        let full = map_of(5, 2);
        let ring = Ring::build(&full);
        let after = Ring::build(&without_replica(&full, 4));
        for a in 0..2048u32 {
            let old = ring.owners(a);
            let new = after.owners(a);
            assert_eq!(new.len(), 2);
            for slot in old.iter().filter(|&&s| s != 4) {
                assert!(
                    new.contains(slot),
                    "agent {a}: surviving owner {slot} lost ownership ({old:?} -> {new:?})"
                );
            }
        }
    }

    #[test]
    fn owners_distinct_and_clamped() {
        // R larger than the live fleet clamps; owners are distinct
        let ring = Ring::build(&map_of(3, 8));
        for a in 0..256u32 {
            let own = ring.owners(a);
            assert_eq!(own.len(), 3);
            let mut sorted = own.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 3, "duplicate owners for agent {a}: {own:?}");
        }
        // empty ring: serve-everything semantics
        let empty = Ring::build(&ShardMap::default());
        assert!(empty.owners(7).is_empty());
        assert!(empty.is_owner(7, 0));
    }

    #[test]
    fn holder_installs_only_newer_maps() {
        let holder = MapHolder::new(map_of(3, 2));
        assert_eq!(holder.version(), 1);
        assert!(!holder.install(map_of(3, 2)), "same version must not install");
        let v2 = without_replica(&map_of(3, 2), 2);
        assert!(holder.install(v2.clone()));
        assert_eq!(holder.version(), 2);
        assert!(!holder.install(map_of(3, 2)), "older map must not roll back");
        let (map, ring) = holder.get();
        assert_eq!(map.live(), vec![0, 1]);
        assert_eq!(ring.live(), 2);
    }
}
