//! InfServer: batched remote inference (paper §3.2).
//!
//! Actors delegate their neural-net forward passes here; the server
//! collects observations from many actors into one batch (size- or
//! timeout-triggered) and runs the `infer_<env>_b{B}` artifact — the
//! SEED-RL design point the paper adopts: batch-32 forward passes are
//! far cheaper per row than 32 batch-1 passes (ablation A2).
//!
//! Requests may carry MANY rows (a vectorized actor submits all of its
//! env slots' observations for one model in a single `InferReq`); the
//! batcher accounts queue depth in forward-pass rows, packs whole
//! requests into artifact-sized chunks, and demuxes each reply back to
//! its request row-for-row.
//!
//! Parameters are fetched from the ModelPool and cached: frozen models
//! forever, the in-training model with a short TTL so actors follow the
//! learner's updates.

use crate::model_pool::{LatestFetch, ModelPoolClient};
use crate::proto::{ModelBlob, ModelKey, Msg, TraceCtx};
use crate::runtime::{Engine, Tensor};
use crate::telemetry::trace;
use crate::transport::{RepServer, Reply, Responder, ServerOpts};
use crate::util::metrics::{Meter, MetricsHub};
use crate::util::sync::OrderedMutex;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar};
use std::time::{Duration, Instant};

struct Pending {
    obs: Vec<f32>,
    /// forward-pass rows this request occupies (wire rows / manifest
    /// agents-per-pass; a team meta-agent row counts once)
    rows: usize,
    /// out-of-band reply handle into the transport event loop; the
    /// connection stays parked (no further reads) until this fires
    responder: Responder,
    enqueued: Instant,
    /// propagated trace context of a sampled request (None = untraced)
    trace: Option<TraceCtx>,
}

/// Requests bucketed per model: the learning model and frozen opponents
/// batch independently, so a stale partial batch for one key never
/// head-of-line blocks a full batch for another.
#[derive(Default)]
struct Queues {
    by_key: HashMap<ModelKey, Vec<Pending>>,
}

/// Pop requests for `key` FIFO until `max_rows` forward-pass rows are
/// gathered.  Always takes at least one request — an oversized request
/// (more rows than one artifact batch) is taken whole and chunked by
/// `run_batch`.
fn take_batch(q: &mut Queues, key: ModelKey, max_rows: usize) -> Vec<Pending> {
    let Some(v) = q.by_key.get_mut(&key) else { return Vec::new() };
    let mut taken = Vec::new();
    let mut rows = 0usize;
    while !v.is_empty() && (taken.is_empty() || rows + v[0].rows <= max_rows) {
        rows += v[0].rows;
        taken.push(v.remove(0));
    }
    if v.is_empty() {
        q.by_key.remove(&key);
    }
    taken
}

fn queued_rows(v: &[Pending]) -> usize {
    v.iter().map(|p| p.rows).sum()
}

/// Slice `lrow`/`vrow`-wide output rows back to their pending requests
/// in queue order, consuming each request's responder.
fn deliver_rows(
    batch: Vec<Pending>,
    logits: &[f32],
    value: &[f32],
    lrow: usize,
    vrow: usize,
) {
    let (mut lo, mut vo) = (0usize, 0usize);
    for p in batch {
        let (ln, vn) = (p.rows * lrow, p.rows * vrow);
        let t0 = Instant::now();
        p.responder.send(Reply::Msg(Msg::InferResp {
            logits: logits[lo..lo + ln].to_vec(),
            value: value[vo..vo + vn].to_vec(),
        }));
        // reply-scatter span closes the server side of a traced chain
        if let Some(ctx) = p.trace {
            trace::finish_span(
                ctx,
                ctx.span_id,
                "inf_reply",
                "inf-server",
                t0,
                p.rows as u32,
            );
        }
        lo += ln;
        vo += vn;
    }
}

pub struct InfServerConfig {
    pub env: String,
    /// slots per forward pass (manifest infer_b)
    pub batch: usize,
    /// max time the oldest request waits before a partial batch runs
    pub max_wait: Duration,
    /// TTL for the non-frozen (learning) model's cached params
    pub refresh: Duration,
    /// transport event-loop threads for the REQ/REP front (0 = auto)
    pub net_threads: usize,
}

pub struct InfServer {
    pub addr: String,
    stop: Arc<AtomicBool>,
    batcher: Option<std::thread::JoinHandle<()>>,
    _server: RepServer,
    /// rows served / batches run — exposes the batching efficiency
    /// (hub meters `rows` / `passes`; gauge `batch_fill` = rows per
    /// forward pass over the artifact batch size)
    pub rows_meter: Arc<Meter>,
    pub batch_meter: Arc<Meter>,
    /// telemetry registry this server's meters live in
    pub hub: Arc<MetricsHub>,
}

struct CacheEntry {
    params: Arc<Vec<f32>>,
    /// device-buffer cache id (bumped on every refetch)
    buf_id: u64,
    frozen: bool,
    /// pool rev stamp from the if-newer path (0 = fetched exact)
    rev: u64,
    fetched: Instant,
}

impl InfServer {
    pub fn start(
        bind: &str,
        cfg: InfServerConfig,
        engine: Arc<Engine>,
        pool_addrs: &[String],
    ) -> Result<InfServer> {
        Self::start_with_hub(
            bind,
            cfg,
            engine,
            pool_addrs,
            Arc::new(MetricsHub::default()),
        )
    }

    /// Like [`start`](InfServer::start), but registering the server's
    /// meters in an externally owned hub (the telemetry plane's role
    /// hub, snapshotted by the worker heartbeat / thread-mode reporter).
    pub fn start_with_hub(
        bind: &str,
        cfg: InfServerConfig,
        engine: Arc<Engine>,
        pool_addrs: &[String],
        hub: Arc<MetricsHub>,
    ) -> Result<InfServer> {
        let m = engine.manifest.env(&cfg.env)?;
        let obs_dim = m.obs_dim;
        // env-slot rows per forward-pass row (2 for team manifests)
        let rows_per_pass = m.n_agents();
        let row_width = rows_per_pass * obs_dim;
        let queue =
            Arc::new((OrderedMutex::new("inference.queue", Queues::default()), Condvar::new()));
        let q2 = queue.clone();
        // async service: the handler only queues the request — the reply
        // is injected back into the event loop by the batcher thread via
        // the Responder, so no server thread blocks per in-flight request
        let server = RepServer::serve_async(
            bind,
            ServerOpts { net_threads: cfg.net_threads, ..ServerOpts::default() },
            move |msg, responder| match msg {
                Msg::InferReq { key, obs, rows, trace } => {
                    // validate against the manifest BEFORE queueing: a
                    // mis-sized request would mis-slice the whole batch
                    if rows == 0
                        || obs.len() != rows as usize * obs_dim
                        || rows as usize % rows_per_pass != 0
                    {
                        responder.send(Reply::Msg(Msg::Err(format!(
                            "infserver: obs len {} / rows {rows} mismatch \
                             (obs_dim {obs_dim}, {rows_per_pass} rows per pass)",
                            obs.len()
                        ))));
                        return;
                    }
                    let pass_rows = rows as usize / rows_per_pass;
                    let (lock, cv) = &*q2;
                    lock.lock().by_key.entry(key).or_default().push(Pending {
                        obs,
                        rows: pass_rows,
                        responder,
                        enqueued: Instant::now(),
                        trace,
                    });
                    cv.notify_one();
                }
                Msg::Ping => responder.send(Reply::Msg(Msg::Pong)),
                other => responder.send(Reply::Msg(Msg::Err(format!(
                    "infserver: unexpected {other:?}"
                )))),
            },
        )?;

        let stop = Arc::new(AtomicBool::new(false));
        let rows_meter = hub.meter("rows");
        let batch_meter = hub.meter("passes");
        let fill = hub.rolling("batch_fill");
        // queue-wait latency distribution: recorded for EVERY request at
        // batch dispatch (cheap atomic bump), independent of span
        // sampling — percentiles flow even with tracing off
        let queue_wait = hub.hist("queue_wait_us");
        // server-side bandwidth rides the same role snapshot
        hub.register("bytes_in", server.bytes_in.clone());
        hub.register("bytes_out", server.bytes_out.clone());
        let pool = ModelPoolClient::connect(pool_addrs);
        let stop2 = stop.clone();
        let rm = rows_meter.clone();
        let bm = batch_meter.clone();
        let addr = server.addr.clone();
        let batcher = std::thread::Builder::new()
            .name("infserver-batcher".into())
            .spawn(move || {
                let mut cache: HashMap<ModelKey, CacheEntry> = HashMap::new();
                // batch assembly buffer, reused across iterations
                let mut obs_buf: Vec<f32> = Vec::new();
                loop {
                    // deadline-driven wake: dispatch any FULL key at
                    // once; otherwise sleep on the condvar until the
                    // earliest per-key deadline (oldest request +
                    // max_wait) and dispatch that key partial
                    let (key, batch) = {
                        let (lock, cv) = &*queue;
                        let mut q = lock.lock();
                        loop {
                            if stop2.load(Ordering::Relaxed) {
                                // fail queued requests instead of leaving
                                // their connections parked
                                for (_, v) in q.by_key.drain() {
                                    for p in v {
                                        p.responder.send(Reply::Msg(Msg::Err(
                                            "infserver shutting down".into(),
                                        )));
                                    }
                                }
                                return;
                            }
                            if let Some(key) = q
                                .by_key
                                .iter()
                                .find(|(_, v)| queued_rows(v) >= cfg.batch)
                                .map(|(k, _)| *k)
                            {
                                break (key, take_batch(&mut q, key, cfg.batch));
                            }
                            let oldest = q
                                .by_key
                                .iter()
                                .filter(|(_, v)| !v.is_empty())
                                .map(|(k, v)| {
                                    let t0 = v
                                        .iter()
                                        .map(|p| p.enqueued)
                                        .min()
                                        .expect("nonempty");
                                    (*k, t0)
                                })
                                .min_by_key(|&(_, t0)| t0);
                            // cap waits so the stop flag stays responsive
                            let idle = Duration::from_millis(20);
                            let wait = match oldest {
                                None => idle,
                                Some((key, t0)) => {
                                    let deadline = t0 + cfg.max_wait;
                                    let now = Instant::now();
                                    if now >= deadline {
                                        break (
                                            key,
                                            take_batch(&mut q, key, cfg.batch),
                                        );
                                    }
                                    (deadline - now).min(idle)
                                }
                            };
                            let (g, _t) = lock.wait_timeout(cv, q, wait);
                            q = g;
                        }
                    };
                    if batch.is_empty() {
                        continue;
                    }
                    // dispatch point: the enqueue→dispatch wait is over
                    for p in &batch {
                        queue_wait.record_micros(p.enqueued.elapsed());
                        if let Some(ctx) = p.trace {
                            trace::finish_span(
                                ctx,
                                ctx.span_id,
                                "inf_queue_wait",
                                "inf-server",
                                p.enqueued,
                                p.rows as u32,
                            );
                        }
                    }
                    let compute_t0 = Instant::now();
                    let params = Self::params_for(
                        &mut cache, &pool, &engine, key, cfg.refresh,
                    );
                    let reply_err = |items: Vec<Pending>, e: &str| {
                        for it in items {
                            it.responder
                                .send(Reply::Msg(Msg::Err(e.to_string())));
                        }
                    };
                    let Some((params, buf_id)) = params else {
                        reply_err(batch, "model not found");
                        continue;
                    };
                    match Self::run_batch(
                        &engine, &cfg, &params, buf_id, &batch, row_width,
                        &mut obs_buf,
                    ) {
                        Ok((logits, value, passes)) => {
                            let rows = queued_rows(&batch);
                            rm.add(rows as u64);
                            bm.add(passes);
                            // occupancy of the forward passes just run:
                            // 1.0 = every artifact slot carried a row
                            fill.push(
                                rows as f64
                                    / (passes.max(1) as usize * cfg.batch.max(1))
                                        as f64,
                            );
                            // one compute span per batch, tagged with the
                            // first traced request's chain (covers param
                            // fetch + forward passes + demux)
                            if let Some(ctx) =
                                batch.iter().find_map(|p| p.trace)
                            {
                                trace::finish_span(
                                    ctx,
                                    ctx.span_id,
                                    "inf_compute",
                                    "inf-server",
                                    compute_t0,
                                    rows as u32,
                                );
                            }
                            deliver_rows(
                                batch,
                                &logits,
                                &value,
                                logits.len() / rows,
                                value.len() / rows,
                            );
                        }
                        Err(e) => reply_err(batch, &format!("{e}")),
                    }
                }
            })?;

        Ok(InfServer {
            addr,
            stop,
            batcher: Some(batcher),
            _server: server,
            rows_meter,
            batch_meter,
            hub,
        })
    }

    /// Cache-install a fetched blob, evicting the predecessor's device
    /// buffer.
    fn install(
        cache: &mut HashMap<ModelKey, CacheEntry>,
        engine: &Engine,
        key: ModelKey,
        blob: ModelBlob,
        rev: u64,
    ) -> (Arc<Vec<f32>>, u64) {
        let params = Arc::new(blob.params);
        let buf_id = crate::runtime::new_cache_id();
        if let Some(old) = cache.insert(
            key,
            CacheEntry {
                params: params.clone(),
                buf_id,
                frozen: blob.frozen,
                rev,
                fetched: Instant::now(),
            },
        ) {
            engine.evict_cached(old.buf_id);
        }
        (params, buf_id)
    }

    fn params_for(
        cache: &mut HashMap<ModelKey, CacheEntry>,
        pool: &ModelPoolClient,
        engine: &Engine,
        key: ModelKey,
        ttl: Duration,
    ) -> Option<(Arc<Vec<f32>>, u64)> {
        if let Some(e) = cache.get(&key) {
            if e.frozen || e.fetched.elapsed() < ttl {
                return Some((e.params.clone(), e.buf_id));
            }
            // TTL expired on the in-training model: delta-aware refresh.
            // A NotModified reply costs O(1) bytes instead of the params
            // payload, and steady state is almost always NotModified.
            match pool.get_latest_if_newer(key.agent, key.version, e.rev) {
                Ok(LatestFetch::NotModified) => {
                    let e = cache.get_mut(&key).expect("entry checked above");
                    e.fetched = Instant::now();
                    return Some((e.params.clone(), e.buf_id));
                }
                Ok(LatestFetch::New { rev, blob }) if blob.key == key => {
                    return Some(Self::install(cache, engine, key, blob, rev));
                }
                // the pool moved past this version (or errored): fall
                // through to the exact fetch — requests pin `key`
                _ => {}
            }
        }
        match pool.get(key) {
            Ok(Some(blob)) => Some(Self::install(cache, engine, key, blob, 0)),
            _ => cache.get(&key).map(|e| (e.params.clone(), e.buf_id)),
        }
    }

    /// Pack the batch's forward-pass rows into artifact-sized chunks
    /// (zero-padding the tail) and run each chunk.  Returns exactly
    /// `total` output rows of logits/values plus the number of forward
    /// passes executed; the caller demuxes them back to the pending
    /// requests.  The common case — everything fits one artifact batch,
    /// which `take_batch`'s row cap guarantees unless a single oversized
    /// request arrived — runs one pass and just truncates the padded
    /// tail off the engine outputs.
    fn run_batch(
        engine: &Engine,
        cfg: &InfServerConfig,
        params: &[f32],
        buf_id: u64,
        batch: &[Pending],
        row_width: usize,
        obs_buf: &mut Vec<f32>,
    ) -> Result<(Vec<f32>, Vec<f32>, u64)> {
        let b = cfg.batch;
        let total: usize = batch.iter().map(|p| p.rows).sum();
        anyhow::ensure!(total > 0, "empty batch");
        if total <= b {
            obs_buf.clear();
            obs_buf.resize(b * row_width, 0.0);
            let mut off = 0usize;
            for p in batch {
                obs_buf[off..off + p.obs.len()].copy_from_slice(&p.obs);
                off += p.obs.len();
            }
            let (mut logits, mut value) =
                engine.infer_cached(&cfg.env, b, buf_id, params, obs_buf)?;
            logits.truncate(total * (logits.len() / b));
            value.truncate(total * (value.len() / b));
            return Ok((logits, value, 1));
        }
        // oversized request(s): flatten the pass rows and chunk
        let rows: Vec<&[f32]> =
            batch.iter().flat_map(|p| p.obs.chunks(row_width)).collect();
        let mut logits_all: Vec<f32> = Vec::new();
        let mut value_all: Vec<f32> = Vec::new();
        let mut passes = 0u64;
        for chunk in rows.chunks(b) {
            obs_buf.clear();
            obs_buf.resize(b * row_width, 0.0);
            for (i, r) in chunk.iter().enumerate() {
                obs_buf[i * row_width..(i + 1) * row_width].copy_from_slice(r);
            }
            let (logits, value) =
                engine.infer_cached(&cfg.env, b, buf_id, params, obs_buf)?;
            let lrow = logits.len() / b;
            let vrow = value.len() / b;
            logits_all.extend_from_slice(&logits[..chunk.len() * lrow]);
            value_all.extend_from_slice(&value[..chunk.len() * vrow]);
            passes += 1;
        }
        Ok((logits_all, value_all, passes))
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.batcher.take() {
            h.join().ok();
        }
    }
}

impl Drop for InfServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// used by tests and the actor's remote backend
pub fn infer_remote(
    client: &crate::transport::ReqClient,
    key: ModelKey,
    obs: &[f32],
    rows: u32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    infer_remote_traced(client, key, obs, rows, None)
}

/// [`infer_remote`] carrying an optional trace context: a sampled
/// request propagates its `TraceCtx` so the server parents its
/// queue-wait/compute/reply spans under the caller's span.
pub fn infer_remote_traced(
    client: &crate::transport::ReqClient,
    key: ModelKey,
    obs: &[f32],
    rows: u32,
    trace: Option<TraceCtx>,
) -> Result<(Vec<f32>, Vec<f32>)> {
    match client.request(&Msg::InferReq { key, obs: obs.to_vec(), rows, trace })? {
        Msg::InferResp { logits, value } => Ok((logits, value)),
        other => anyhow::bail!("infer: unexpected reply {other:?}"),
    }
}

/// Local-engine forward pass for `rows` pass rows (`obs` holds
/// `rows * n_agents * obs_dim` f32s), chunked through the wide
/// `infer_<env>_b{infer_b}` artifact when `rows > 1` — the Actor's
/// Local-backend equivalent of a multi-row `InferReq` — and through the
/// b1 artifact when `rows == 1` (the pre-vectorized fast path).  The
/// tail chunk is zero-padded to the artifact batch; pad rows are
/// sliced off the outputs.  Used by the vectorized Actor and the eval
/// batch helpers.
pub fn infer_local_rows(
    engine: &Engine,
    env: &str,
    params_id: u64,
    params: &[f32],
    obs: &[f32],
    rows: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    anyhow::ensure!(rows > 0, "infer_local_rows: zero rows");
    anyhow::ensure!(
        obs.len() % rows == 0,
        "infer_local_rows: {} obs not divisible into {rows} rows",
        obs.len()
    );
    if rows == 1 {
        return engine.infer_cached(env, 1, params_id, params, obs);
    }
    let b = engine.manifest.env(env)?.infer_b.max(1);
    let row_width = obs.len() / rows;
    // Tiny gathers loop the b1 artifact instead of paying a mostly-
    // padded wide pass: below b/8 rows the padding waste outweighs the
    // per-pass dispatch overhead the wide artifact amortizes (A2).
    // This also keeps a 1-slot actor whose opponent draw shares the
    // learner's key at the pre-vectorized cost (two b1 passes).
    if rows * 8 <= b {
        let mut logits = Vec::new();
        let mut value = Vec::new();
        for r in 0..rows {
            let (l, v) = engine.infer_cached(
                env,
                1,
                params_id,
                params,
                &obs[r * row_width..(r + 1) * row_width],
            )?;
            logits.extend_from_slice(&l);
            value.extend_from_slice(&v);
        }
        return Ok((logits, value));
    }
    let mut logits = Vec::new();
    let mut value = Vec::new();
    // pad buffer only materializes for a partial tail chunk
    let mut buf: Vec<f32> = Vec::new();
    let mut done = 0usize;
    while done < rows {
        let take = (rows - done).min(b);
        let src = &obs[done * row_width..(done + take) * row_width];
        let (l, v) = if take == b {
            engine.infer_cached(env, b, params_id, params, src)?
        } else {
            buf.clear();
            buf.resize(b * row_width, 0.0);
            buf[..take * row_width].copy_from_slice(src);
            engine.infer_cached(env, b, params_id, params, &buf)?
        };
        let lrow = l.len() / b;
        let vrow = v.len() / b;
        logits.extend_from_slice(&l[..take * lrow]);
        value.extend_from_slice(&v[..take * vrow]);
        done += take;
    }
    Ok((logits, value))
}

#[allow(unused_imports)]
use Tensor as _TensorUnused;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_pool::ModelPoolServer;
    use crate::proto::ModelBlob;
    use crate::transport::ReqClient;
    use std::path::PathBuf;

    fn engine() -> Option<Arc<Engine>> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Arc::new(Engine::load(dir).unwrap()))
    }

    #[test]
    fn batched_inference_matches_local() {
        let Some(engine) = engine() else { return };
        let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let pc = ModelPoolClient::connect(&[pool.addr.clone()]);
        let params = engine.init_params("rps").unwrap();
        let key = ModelKey::new(0, 1);
        pc.put(ModelBlob { key, params: params.clone(), hp: vec![], frozen: true })
            .unwrap();

        let m = engine.manifest.env("rps").unwrap().clone();
        let server = InfServer::start(
            "127.0.0.1:0",
            InfServerConfig {
                env: "rps".into(),
                batch: m.infer_b,
                max_wait: Duration::from_millis(2),
                refresh: Duration::from_millis(50),
                net_threads: 0,
            },
            engine.clone(),
            &[pool.addr.clone()],
        )
        .unwrap();

        let client = ReqClient::connect(&server.addr);
        let obs = vec![1.0f32, 0.0, 0.0, 0.0];
        let (logits, value) = infer_remote(&client, key, &obs, 1).unwrap();
        let (l_local, v_local) = engine.infer("rps", 1, &params, &obs).unwrap();
        assert_eq!(logits.len(), m.act_dim);
        for (a, b) in logits.iter().zip(&l_local) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert!((value[0] - v_local[0]).abs() < 1e-4);
    }

    #[test]
    fn many_concurrent_clients_get_batched() {
        let Some(engine) = engine() else { return };
        let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let pc = ModelPoolClient::connect(&[pool.addr.clone()]);
        let params = engine.init_params("rps").unwrap();
        let key = ModelKey::new(0, 1);
        pc.put(ModelBlob { key, params, hp: vec![], frozen: true }).unwrap();
        let m = engine.manifest.env("rps").unwrap().clone();
        let server = InfServer::start(
            "127.0.0.1:0",
            InfServerConfig {
                env: "rps".into(),
                batch: m.infer_b,
                max_wait: Duration::from_millis(5),
                refresh: Duration::from_millis(50),
                net_threads: 0,
            },
            engine,
            &[pool.addr.clone()],
        )
        .unwrap();
        let addr = server.addr.clone();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let c = ReqClient::connect(&addr);
                    for _ in 0..12 {
                        let (l, _) =
                            infer_remote(&c, key, &[1.0, 0.0, 0.0, 0.0], 1)
                                .unwrap();
                        assert_eq!(l.len(), 3);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let rows = server.rows_meter.count();
        let batches = server.batch_meter.count();
        assert_eq!(rows, 96);
        assert!(batches < rows, "some batching must happen: {batches} batches");
    }

    /// The `rows` field is validated against the manifest: a claimed
    /// shape that doesn't match `obs.len()` is rejected up front instead
    /// of silently mis-slicing the batch.
    #[test]
    fn mismatched_rows_rejected() {
        let Some(engine) = engine() else { return };
        let m = engine.manifest.env("rps").unwrap().clone();
        let (d, act_dim) = (m.obs_dim, m.act_dim);
        let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let pc = ModelPoolClient::connect(&[pool.addr.clone()]);
        let params = engine.init_params("rps").unwrap();
        let key = ModelKey::new(0, 1);
        pc.put(ModelBlob { key, params, hp: vec![], frozen: true }).unwrap();
        let server = InfServer::start(
            "127.0.0.1:0",
            InfServerConfig {
                env: "rps".into(),
                batch: m.infer_b,
                max_wait: Duration::from_millis(1),
                refresh: Duration::from_millis(50),
                net_threads: 0,
            },
            engine,
            &[pool.addr.clone()],
        )
        .unwrap();
        let c = ReqClient::connect(&server.addr);
        // obs holds one row but the header claims two
        let reply = c
            .request(&Msg::InferReq { key, obs: vec![0.0; d], rows: 2, trace: None })
            .unwrap();
        assert!(matches!(reply, Msg::Err(_)), "got {reply:?}");
        // zero rows is never valid
        let reply = c
            .request(&Msg::InferReq { key, obs: vec![], rows: 0, trace: None })
            .unwrap();
        assert!(matches!(reply, Msg::Err(_)), "got {reply:?}");
        // a well-formed request on the SAME connection still succeeds
        let (logits, _) = infer_remote(&c, key, &vec![0.0; d], 1).unwrap();
        assert_eq!(logits.len(), act_dim);
    }

    /// A vectorized actor's multi-row request comes back demuxed
    /// row-for-row, matching per-row local inference; rows beyond one
    /// artifact batch exercise the chunked dispatch.
    #[test]
    fn multi_row_requests_demux_per_row() {
        let Some(engine) = engine() else { return };
        let m = engine.manifest.env("rps").unwrap().clone();
        let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let pc = ModelPoolClient::connect(&[pool.addr.clone()]);
        let params = engine.init_params("rps").unwrap();
        let key = ModelKey::new(0, 1);
        pc.put(ModelBlob {
            key,
            params: params.clone(),
            hp: vec![],
            frozen: true,
        })
        .unwrap();
        let server = InfServer::start(
            "127.0.0.1:0",
            InfServerConfig {
                env: "rps".into(),
                batch: m.infer_b,
                max_wait: Duration::from_millis(2),
                refresh: Duration::from_millis(50),
                net_threads: 0,
            },
            engine.clone(),
            &[pool.addr.clone()],
        )
        .unwrap();
        let c = ReqClient::connect(&server.addr);
        let d = m.obs_dim;
        let rows = 5usize;
        let obs: Vec<f32> = (0..rows * d).map(|i| i as f32 * 0.1).collect();
        let (logits, value) = infer_remote(&c, key, &obs, rows as u32).unwrap();
        assert_eq!(logits.len(), rows * m.act_dim);
        assert_eq!(value.len(), rows);
        for r in 0..rows {
            let (l1, v1) = engine
                .infer("rps", 1, &params, &obs[r * d..(r + 1) * d])
                .unwrap();
            for (a, b) in
                logits[r * m.act_dim..(r + 1) * m.act_dim].iter().zip(&l1)
            {
                assert!((a - b).abs() < 1e-4, "row {r}: {a} vs {b}");
            }
            assert!((value[r] - v1[0]).abs() < 1e-4, "row {r} value");
        }
        // more rows than one artifact batch: chunked dispatch
        let rows = m.infer_b + 3;
        let obs = vec![0.25f32; rows * d];
        let (logits, value) = infer_remote(&c, key, &obs, rows as u32).unwrap();
        assert_eq!(logits.len(), rows * m.act_dim);
        assert_eq!(value.len(), rows);
        // identical rows must produce matching logits across chunks
        for r in 1..rows {
            for (a, b) in logits[r * m.act_dim..(r + 1) * m.act_dim]
                .iter()
                .zip(&logits[..m.act_dim])
            {
                assert!((a - b).abs() < 1e-5, "row {r} diverged from row 0");
            }
        }
    }

    #[test]
    fn unknown_model_reports_error() {
        let Some(engine) = engine() else { return };
        let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let server = InfServer::start(
            "127.0.0.1:0",
            InfServerConfig {
                env: "rps".into(),
                batch: 4,
                max_wait: Duration::from_millis(1),
                refresh: Duration::from_millis(50),
                net_threads: 0,
            },
            engine,
            &[pool.addr.clone()],
        )
        .unwrap();
        let c = ReqClient::connect(&server.addr);
        let reply = c
            .request(&Msg::InferReq {
                key: ModelKey::new(9, 9),
                obs: vec![0.0; 4],
                rows: 1,
                trace: None,
            })
            .unwrap();
        assert!(matches!(reply, Msg::Err(_)));
    }

    /// Satellite: a traced InferReq leaves the complete server-side span
    /// chain — enqueue→dispatch wait, batch compute, reply scatter — in
    /// the flight recorder, every span parented on the caller's span id,
    /// and the queue-wait histogram records the request regardless.
    #[test]
    fn traced_request_leaves_complete_span_chain() {
        let Some(engine) = engine() else { return };
        let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let pc = ModelPoolClient::connect(&[pool.addr.clone()]);
        let params = engine.init_params("rps").unwrap();
        let key = ModelKey::new(0, 1);
        pc.put(ModelBlob { key, params, hp: vec![], frozen: true }).unwrap();
        let m = engine.manifest.env("rps").unwrap().clone();
        let server = InfServer::start(
            "127.0.0.1:0",
            InfServerConfig {
                env: "rps".into(),
                batch: m.infer_b,
                max_wait: Duration::from_millis(1),
                refresh: Duration::from_millis(50),
                net_threads: 0,
            },
            engine,
            &[pool.addr.clone()],
        )
        .unwrap();
        let hist_before = server.hub.hist("queue_wait_us").count();
        let client = ReqClient::connect(&server.addr);
        let ctx = TraceCtx {
            trace_id: trace::next_id(),
            span_id: trace::next_id(),
        };
        let (logits, _) =
            infer_remote_traced(&client, key, &[1.0, 0.0, 0.0, 0.0], 1, Some(ctx))
                .unwrap();
        assert_eq!(logits.len(), m.act_dim);
        // non-destructive snapshot: lib tests run in parallel and share
        // the process-global recorder, so draining here would race
        let spans: Vec<_> = trace::recorder()
            .snapshot()
            .into_iter()
            .filter(|s| s.trace_id == ctx.trace_id)
            .collect();
        for want in ["inf_queue_wait", "inf_compute", "inf_reply"] {
            let s = spans
                .iter()
                .find(|s| s.name == want)
                .unwrap_or_else(|| panic!("missing {want} span in {spans:?}"));
            assert_eq!(s.parent, ctx.span_id, "{want} must parent on the caller");
            assert_eq!(s.role, "inf-server");
            assert!(s.rows >= 1, "{want} span carries its row count");
        }
        // the latency histogram is span-independent but must cover this
        // request too
        assert!(
            server.hub.hist("queue_wait_us").count() > hist_before,
            "queue_wait_us must record every dispatched request"
        );
    }
}
