//! Throughput meters and rolling statistics.
//!
//! rfps / cfps — the paper's two headline throughput counters (§4.4):
//! frames received from Actors vs frames consumed by the Learner.  All
//! counters are lock-free atomics so the hot paths never block on
//! metrics; a `MetricsHub` aggregates and renders Table-3-style rows.
//!
//! The telemetry plane (see DESIGN.md §Telemetry plane) is built on
//! **interval snapshots**: [`Meter::take_snapshot`] atomically drains
//! the delta since the previous snapshot, and [`MetricsHub::snapshot`]
//! packages every registered meter's delta plus every rolling gauge's
//! current window into one report a worker can piggyback on its
//! heartbeat.  Rates derived from snapshots reflect the *current*
//! interval, not a lifetime average.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotonic event counter with delta-based rate derivation.
///
/// `count()` never decreases (hot-path callers budget against it), so
/// interval accounting rides a separate snapshot base: each
/// [`take_snapshot`](Meter::take_snapshot) drains the events recorded
/// since the previous one.  Every `add` lands in exactly one snapshot's
/// delta — there is no reset window in which events can be lost or
/// misattributed (the old `reset()` stored the counter and the epoch
/// non-atomically and had exactly that bug).
pub struct Meter {
    count: AtomicU64,
    /// `count` as of the last snapshot
    snap_base: AtomicU64,
    /// epoch of the last snapshot (creation time initially); the lock
    /// also serializes concurrent snapshotters so each delta pairs with
    /// the interval it was collected over
    snap_at: Mutex<Instant>,
}

impl Default for Meter {
    fn default() -> Self {
        Self::new()
    }
}

impl Meter {
    pub fn new() -> Self {
        Meter {
            count: AtomicU64::new(0),
            snap_base: AtomicU64::new(0),
            snap_at: Mutex::new(Instant::now()),
        }
    }
    #[inline]
    pub fn add(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
    }
    /// Lifetime total — monotonic, unaffected by snapshots.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
    /// Drain the current interval: `(events since last snapshot,
    /// seconds since last snapshot)`, then start a fresh interval.
    /// Deltas telescope — the sum of every snapshot's delta plus the
    /// not-yet-snapshotted remainder always equals `count()`.
    pub fn take_snapshot(&self) -> (u64, f64) {
        let mut at = self.snap_at.lock().unwrap();
        let total = self.count.load(Ordering::Relaxed);
        let delta = total - self.snap_base.swap(total, Ordering::Relaxed);
        let now = Instant::now();
        let secs = now.duration_since(*at).as_secs_f64();
        *at = now;
        (delta, secs)
    }
    /// Events per second over the current interval (since the last
    /// `take_snapshot`; since creation if never snapshotted).  Does not
    /// consume the interval.
    pub fn rate(&self) -> f64 {
        let at = self.snap_at.lock().unwrap();
        let secs = at.elapsed().as_secs_f64();
        let delta = self.count() - self.snap_base.load(Ordering::Relaxed);
        if secs <= 0.0 {
            0.0
        } else {
            delta as f64 / secs
        }
    }
}

/// Number of log-spaced latency buckets in a [`Hist`].
pub const HIST_BUCKETS: usize = 64;

/// Lock-cheap log-bucketed latency histogram (~power-of-√2 buckets).
///
/// Two sub-buckets per octave: bucket `2k` covers `[2^k, 1.5·2^k)` and
/// bucket `2k+1` covers `[1.5·2^k, 2^(k+1))` (buckets 0 and 1 hold the
/// exact values 0 and 1), so any recorded value lands within ~25% of
/// its bucket's representative midpoint — plenty for p50/p95/p99 tail
/// reporting.  64 buckets span `[0, 2^32)`; in microseconds that is
/// over an hour, far beyond any request-path latency.
///
/// `record` is a single relaxed atomic increment (no lock, no
/// allocation), so the inference and rollout hot paths can record every
/// request.  Histograms merge by bucket-wise addition, and interval
/// snapshots telescope exactly like [`Meter::take_snapshot`]: each
/// bucket keeps a snapshot base, so every recorded event lands in
/// exactly one snapshot's delta.
pub struct Hist {
    buckets: [AtomicU64; HIST_BUCKETS],
    /// per-bucket count as of the last snapshot
    snap_base: [AtomicU64; HIST_BUCKETS],
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

impl Hist {
    pub fn new() -> Self {
        Hist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            snap_base: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Bucket index for a value (typically a latency in microseconds).
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        if v <= 1 {
            return v as usize;
        }
        let bit = 63 - v.leading_zeros() as usize; // >= 1
        (2 * bit + ((v >> (bit - 1)) & 1) as usize).min(HIST_BUCKETS - 1)
    }

    /// Representative (midpoint) value of a bucket, the value quantile
    /// extraction reports for samples that landed in it.
    pub fn bucket_value(idx: usize) -> f64 {
        match idx {
            0 => 0.0,
            1 => 1.0,
            _ => {
                let k = idx / 2;
                let base = (1u64 << k) as f64;
                if idx % 2 == 0 {
                    1.25 * base
                } else {
                    1.75 * base
                }
            }
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a duration in microseconds (the tracing layer's unit).
    #[inline]
    pub fn record_micros(&self, d: std::time::Duration) {
        self.record(d.as_micros() as u64);
    }

    /// Total recorded events (sum over buckets).
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Current per-bucket totals (for merging / quantiles).
    pub fn totals(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Quantile over the lifetime totals; see [`Hist::quantile_of`].
    pub fn quantile(&self, q: f64) -> f64 {
        Self::quantile_of(&self.totals(), q)
    }

    /// Quantile extraction from a (possibly merged) bucket array: the
    /// representative value of the bucket holding the ⌈q·total⌉-th
    /// sample.  Returns 0.0 for an empty histogram.  Monotone in `q`.
    pub fn quantile_of(buckets: &[u64; HIST_BUCKETS], q: f64) -> f64 {
        let total: u64 = buckets.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_value(i);
            }
        }
        Self::bucket_value(HIST_BUCKETS - 1)
    }

    /// Drain the current interval as sparse `(bucket, delta)` pairs.
    /// Per-bucket deltas telescope: summing every snapshot's pairs plus
    /// the not-yet-snapshotted remainder reproduces the lifetime
    /// totals, so a merger accumulating deltas never loses or
    /// double-counts an event.
    pub fn take_snapshot(&self) -> Vec<(u8, u64)> {
        let mut out = Vec::new();
        for i in 0..HIST_BUCKETS {
            let total = self.buckets[i].load(Ordering::Relaxed);
            let delta =
                total - self.snap_base[i].swap(total, Ordering::Relaxed);
            if delta > 0 {
                out.push((i as u8, delta));
            }
        }
        out
    }
}

/// Sparse `(bucket, count)` pairs — the wire/snapshot form of one
/// histogram interval.
pub type HistDelta = Vec<(u8, u64)>;

/// Windowed scalar statistic (mean/min/max over the recent window).
pub struct Rolling {
    inner: Mutex<RollingInner>,
}

struct RollingInner {
    window: Vec<f64>,
    cap: usize,
    next: usize,
}

impl Default for Rolling {
    /// A zero-capacity ring is unusable (the first wrapped push would
    /// index an empty window), so the default is the same 256-sample
    /// window `MetricsHub::rolling` registers.
    fn default() -> Self {
        Rolling::with_capacity(256)
    }
}

impl Rolling {
    pub fn with_capacity(cap: usize) -> Self {
        Rolling {
            inner: Mutex::new(RollingInner {
                window: Vec::with_capacity(cap),
                cap: cap.max(1),
                next: 0,
            }),
        }
    }
    pub fn push(&self, v: f64) {
        let mut g = self.inner.lock().unwrap();
        let cap = g.cap;
        if g.window.len() < cap {
            g.window.push(v);
        } else {
            let i = g.next;
            g.window[i] = v;
            g.next = (i + 1) % cap;
        }
    }
    pub fn mean(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        if g.window.is_empty() {
            return 0.0;
        }
        g.window.iter().sum::<f64>() / g.window.len() as f64
    }
    pub fn minmax(&self) -> (f64, f64) {
        let g = self.inner.lock().unwrap();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &v in &g.window {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if g.window.is_empty() {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().window.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One interval's worth of a hub's metrics: counter deltas collected
/// over `interval_secs`, plus the current rolling-gauge values.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnap {
    pub interval_secs: f64,
    /// meter name → events since the hub's previous snapshot
    pub counters: Vec<(String, u64)>,
    /// rolling name → current window mean
    pub gauges: Vec<(String, f64)>,
    /// histogram name → sparse per-bucket deltas for this interval
    pub hists: Vec<(String, HistDelta)>,
}

/// Named registry shared across modules (one per role instance).
pub struct MetricsHub {
    meters: Mutex<BTreeMap<String, Arc<Meter>>>,
    rollings: Mutex<BTreeMap<String, Arc<Rolling>>>,
    hists: Mutex<BTreeMap<String, Arc<Hist>>>,
    /// epoch of the last hub snapshot (drives `interval_secs`)
    snap_at: Mutex<Instant>,
}

impl Default for MetricsHub {
    fn default() -> Self {
        MetricsHub {
            meters: Mutex::new(BTreeMap::new()),
            rollings: Mutex::new(BTreeMap::new()),
            hists: Mutex::new(BTreeMap::new()),
            snap_at: Mutex::new(Instant::now()),
        }
    }
}

impl MetricsHub {
    pub fn meter(&self, name: &str) -> Arc<Meter> {
        self.meters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Meter::new()))
            .clone()
    }
    /// Adopt an externally owned meter under `name` (e.g. a transport
    /// endpoint's byte counters) so hub snapshots carry it.  Replaces
    /// any meter previously registered under the name; call before the
    /// first snapshot so no interval is split across two meters.
    pub fn register(&self, name: &str, m: Arc<Meter>) {
        self.meters.lock().unwrap().insert(name.to_string(), m);
    }
    pub fn hist(&self, name: &str) -> Arc<Hist> {
        self.hists
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Hist::new()))
            .clone()
    }
    pub fn rolling(&self, name: &str) -> Arc<Rolling> {
        self.rollings
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Rolling::with_capacity(256)))
            .clone()
    }
    /// "name=rate/s" report, sorted by name (used by the throughput
    /// table).  Rates cover the current interval; see [`Meter::rate`].
    pub fn report(&self) -> Vec<(String, f64)> {
        self.meters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, m)| (k.clone(), m.rate()))
            .collect()
    }
    /// Drain one reporting interval: every meter's delta since the
    /// previous hub snapshot plus every gauge's current mean.  Intended
    /// for a single periodic consumer per hub (the role's telemetry
    /// reporter) — concurrent snapshotters would split deltas between
    /// them.
    pub fn snapshot(&self) -> MetricsSnap {
        let interval_secs = {
            let mut at = self.snap_at.lock().unwrap();
            let now = Instant::now();
            let secs = now.duration_since(*at).as_secs_f64();
            *at = now;
            secs
        };
        let counters = self
            .meters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, m)| (k.clone(), m.take_snapshot().0))
            .collect();
        let gauges = self
            .rollings
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, r)| !r.is_empty())
            .map(|(k, r)| (k.clone(), r.mean()))
            .collect();
        // quiet histograms (no events this interval) are omitted, like
        // never-pushed gauges — the merger accumulates deltas, so an
        // empty delta carries no information
        let hists = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .filter_map(|(k, h)| {
                let d = h.take_snapshot();
                (!d.is_empty()).then(|| (k.clone(), d))
            })
            .collect();
        MetricsSnap { interval_secs, counters, gauges, hists }
    }
}

/// Simple wall-clock stopwatch used by the bench harness.
pub struct Stopwatch(Instant);

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_counts() {
        let m = Meter::new();
        m.add(3);
        m.add(4);
        assert_eq!(m.count(), 7);
        assert!(m.rate() > 0.0);
        let (delta, secs) = m.take_snapshot();
        assert_eq!(delta, 7);
        assert!(secs >= 0.0);
        // the lifetime count survives the snapshot; the interval drains
        assert_eq!(m.count(), 7);
        assert_eq!(m.take_snapshot().0, 0);
        m.add(2);
        assert_eq!(m.take_snapshot().0, 2);
        assert_eq!(m.count(), 9);
    }

    /// No-lost-events: with a concurrent adder hammering the meter, the
    /// sum of every snapshot delta must equal the final count — the old
    /// two-store `reset()` dropped or misattributed events that landed
    /// between its stores.
    #[test]
    fn snapshot_deltas_lose_no_events_under_concurrency() {
        let m = Arc::new(Meter::new());
        let m2 = m.clone();
        let adder = std::thread::spawn(move || {
            let mut added = 0u64;
            for i in 0..200_000u64 {
                let n = i % 3 + 1;
                m2.add(n);
                added += n;
            }
            added
        });
        let mut snapped = 0u64;
        while !adder.is_finished() {
            snapped += m.take_snapshot().0;
        }
        let added = adder.join().unwrap();
        snapped += m.take_snapshot().0;
        assert_eq!(snapped, added, "snapshot deltas must telescope");
        assert_eq!(m.count(), added, "lifetime count must be exact");
    }

    /// Regression: `Rolling::default()` used to derive a zero-capacity
    /// ring whose wrap path indexed an empty Vec and panicked on the
    /// first push past the (empty) window.
    #[test]
    fn rolling_default_survives_many_pushes() {
        let r = Rolling::default();
        for v in 0..300 {
            r.push(v as f64);
        }
        assert_eq!(r.len(), 256);
        // window holds {44..=299}: the first 256 pushes fill 0..=255,
        // the remaining 44 overwrite slots 0..=43 with 256..=299
        assert_eq!(r.minmax(), (44.0, 299.0));
        let want = (44..=299).sum::<i64>() as f64 / 256.0;
        assert!((r.mean() - want).abs() < 1e-9, "{} vs {want}", r.mean());
    }

    #[test]
    fn rolling_window_wraps() {
        let r = Rolling::with_capacity(3);
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.push(v);
        }
        // window now holds {4, 2, 3}
        assert_eq!(r.len(), 3);
        assert!((r.mean() - 3.0).abs() < 1e-9);
        assert_eq!(r.minmax(), (2.0, 4.0));
    }

    #[test]
    fn hub_shares_meters() {
        let hub = MetricsHub::default();
        hub.meter("rfps").add(10);
        assert_eq!(hub.meter("rfps").count(), 10);
        assert_eq!(hub.report().len(), 1);
    }

    #[test]
    fn hub_snapshot_drains_deltas_and_reads_gauges() {
        let hub = MetricsHub::default();
        hub.meter("frames").add(40);
        hub.meter("episodes").add(2);
        hub.rolling("lag").push(1.0);
        hub.rolling("lag").push(3.0);
        hub.rolling("empty"); // registered but never pushed: omitted
        let s = hub.snapshot();
        assert!(s.interval_secs >= 0.0);
        assert_eq!(
            s.counters,
            vec![("episodes".into(), 2), ("frames".into(), 40)]
        );
        assert_eq!(s.gauges, vec![("lag".into(), 2.0)]);
        // second snapshot: counters drained, gauge window persists
        hub.meter("frames").add(5);
        let s2 = hub.snapshot();
        assert_eq!(
            s2.counters,
            vec![("episodes".into(), 0), ("frames".into(), 5)]
        );
        assert_eq!(s2.gauges, vec![("lag".into(), 2.0)]);
    }

    #[test]
    fn hist_bucket_boundaries_are_exact() {
        // sub-power-of-two boundaries: [2^k, 1.5·2^k) → 2k,
        // [1.5·2^k, 2^(k+1)) → 2k+1
        assert_eq!(Hist::bucket_of(0), 0);
        assert_eq!(Hist::bucket_of(1), 1);
        for k in 1..31usize {
            let p = 1u64 << k;
            assert_eq!(Hist::bucket_of(p), 2 * k, "2^{k}");
            assert_eq!(Hist::bucket_of(p + p / 2 - 1), 2 * k, "1.5·2^{k}-1");
            assert_eq!(Hist::bucket_of(p + p / 2), 2 * k + 1, "1.5·2^{k}");
            assert_eq!(Hist::bucket_of(2 * p - 1), 2 * k + 1, "2^{}−1", k + 1);
        }
        // everything past the last bucket's range saturates into it
        assert_eq!(Hist::bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // every value sits inside its bucket's representative ±25%
        for v in [2u64, 3, 5, 13, 100, 1_000, 123_456, 1 << 30] {
            let rep = Hist::bucket_value(Hist::bucket_of(v));
            let err = (rep - v as f64).abs() / v as f64;
            assert!(err <= 0.25, "v={v} rep={rep} err={err}");
        }
    }

    /// Merge-of-parts equals whole: recording a stream into K shard
    /// histograms and summing their buckets gives the same quantiles as
    /// recording everything into one histogram.
    #[test]
    fn hist_merge_of_parts_equals_whole() {
        use crate::util::proptest::forall;
        forall(100, "hist-merge", |rng| {
            let whole = Hist::new();
            let parts: Vec<Hist> = (0..4).map(|_| Hist::new()).collect();
            let n = 1 + rng.below(500) as usize;
            for _ in 0..n {
                // spread over ~6 decades so many buckets are exercised
                let v = (rng.next_u32() as u64) >> rng.below(28);
                whole.record(v);
                parts[rng.below(4) as usize].record(v);
            }
            let mut merged = [0u64; HIST_BUCKETS];
            for p in &parts {
                for (i, c) in p.totals().iter().enumerate() {
                    merged[i] += c;
                }
            }
            crate::prop_assert_eq!(merged, whole.totals());
            for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
                crate::prop_assert_eq!(
                    Hist::quantile_of(&merged, q),
                    whole.quantile(q)
                );
            }
            Ok(())
        });
    }

    /// Quantiles are monotone in q and bracketed by the recorded range
    /// (up to the ±25% bucket resolution).
    #[test]
    fn hist_quantiles_monotone_and_bounded() {
        use crate::util::proptest::forall;
        forall(100, "hist-quantile", |rng| {
            let h = Hist::new();
            let n = 1 + rng.below(300) as usize;
            let mut lo = u64::MAX;
            let mut hi = 0u64;
            for _ in 0..n {
                let v = (rng.next_u32() as u64) >> rng.below(24);
                lo = lo.min(v);
                hi = hi.max(v);
                h.record(v);
            }
            let mut prev = -1.0f64;
            for i in 0..=20 {
                let q = i as f64 / 20.0;
                let v = h.quantile(q);
                crate::prop_assert!(v >= prev, "q={q}: {v} < {prev}");
                prev = v;
            }
            crate::prop_assert!(
                h.quantile(1.0) <= hi as f64 * 1.25 + 1.0,
                "p100 {} above max {hi}",
                h.quantile(1.0)
            );
            crate::prop_assert!(
                h.quantile(0.0) >= lo as f64 * 0.75 - 1.0,
                "p0 {} below min {lo}",
                h.quantile(0.0)
            );
            Ok(())
        });
    }

    /// Hist snapshot deltas telescope exactly like Meter's: under a
    /// concurrent recorder, accumulated snapshot deltas plus the final
    /// drain reproduce the lifetime bucket totals.
    #[test]
    fn hist_snapshot_deltas_lose_no_events_under_concurrency() {
        let h = Arc::new(Hist::new());
        let h2 = h.clone();
        let recorder = std::thread::spawn(move || {
            for i in 0..100_000u64 {
                h2.record(i % 4096);
            }
        });
        let mut acc = [0u64; HIST_BUCKETS];
        let mut drain = |acc: &mut [u64; HIST_BUCKETS]| {
            for (i, d) in h.take_snapshot() {
                acc[i as usize] += d;
            }
        };
        while !recorder.is_finished() {
            drain(&mut acc);
        }
        recorder.join().unwrap();
        drain(&mut acc);
        assert_eq!(acc, h.totals(), "hist deltas must telescope");
        assert_eq!(acc.iter().sum::<u64>(), 100_000);
    }

    #[test]
    fn hub_snapshot_carries_hist_deltas() {
        let hub = MetricsHub::default();
        hub.hist("quiet"); // registered, never recorded: omitted
        let h = hub.hist("queue_wait_us");
        h.record(100);
        h.record(100);
        h.record(1 << 20);
        let s = hub.snapshot();
        assert_eq!(s.hists.len(), 1);
        let (name, delta) = &s.hists[0];
        assert_eq!(name, "queue_wait_us");
        assert_eq!(delta.iter().map(|(_, c)| c).sum::<u64>(), 3);
        // drained: a quiet interval omits the hist entirely
        assert!(hub.snapshot().hists.is_empty());
    }
}
