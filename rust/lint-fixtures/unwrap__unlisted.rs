// Seeded-bad fixture: unwraps on a network path (netpath marker) with
// no lint-allow.toml entry — fixtures are linted with an empty list.
// lint: netpath

fn on_bytes(b: &[u8]) -> Msg {
    Msg::from_bytes(b).unwrap()
}

fn header(b: &[u8]) -> u32 {
    u32::from_le_bytes(b[..4].try_into().expect("short header"))
}
