//! VecEnv: N independently-seeded episodes of one [`MultiAgentEnv`].
//!
//! The vectorized rollout substrate (paper §4.4 throughput study): an
//! Actor drives all N slots in lock-step, gathering every slot's
//! observations into one wide forward pass per model instead of N
//! batch-1 passes.  Each slot is a fully independent episode — its own
//! env instance, its own seed stream — so trajectories and outcomes
//! stay per-episode exact.
//!
//! Determinism story: slot seeds derive from the actor's base seed via
//! [`slot_seed`] (splitmix64 mix).  Slot 0 keeps the base seed
//! unchanged, so a 1-slot VecEnv reproduces the single-env actor
//! bit-for-bit.
//!
//! Two driving styles:
//! - granular ([`VecEnv::reset_slot`] / [`VecEnv::step_slot`]) for
//!   callers whose episode starts are gated on external state (the
//!   Actor resets a slot only once its next LeagueMgr task is in hand);
//! - bulk auto-reset ([`VecEnv::step_all`]): finished slots reset
//!   immediately and the episode boundary is surfaced per slot via
//!   [`SlotStep::done`] / [`SlotStep::final_obs`].

use super::{make, Info, MultiAgentEnv, Step};
use anyhow::Result;

/// Mix `slot` into `base` (splitmix64) so every slot gets an
/// independent, reproducible seed.  Slot 0 returns `base` unchanged —
/// a 1-slot VecEnv is bit-identical to the raw env.
pub fn slot_seed(base: u64, slot: usize) -> u64 {
    if slot == 0 {
        return base;
    }
    let mut z = base ^ (slot as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One slot's result from [`VecEnv::step_all`].
pub struct SlotStep {
    /// Observations to act on next tick.  When `done`, these are the
    /// first observations of the slot's auto-reset next episode.
    pub obs: Vec<Vec<f32>>,
    pub rewards: Vec<f32>,
    pub done: bool,
    pub info: Info,
    /// Terminal observations of the finished episode (`done` only).
    pub final_obs: Option<Vec<Vec<f32>>>,
}

/// N parallel instances of one env, independently seeded per slot.
pub struct VecEnv {
    slots: Vec<Box<dyn MultiAgentEnv>>,
    n_agents: usize,
    obs_dim: usize,
    act_dim: usize,
    max_steps: usize,
}

impl VecEnv {
    /// Build `n_slots` instances of env spec `name` (any name
    /// [`super::make`] accepts, including parameterized forms like
    /// `doom_lite:4`), slot `i` seeded with `slot_seed(base_seed, i)`.
    pub fn make(name: &str, n_slots: usize, base_seed: u64) -> Result<VecEnv> {
        anyhow::ensure!(n_slots >= 1, "VecEnv needs at least one slot");
        let slots = (0..n_slots)
            .map(|i| make(name, slot_seed(base_seed, i)))
            .collect::<Result<Vec<_>>>()?;
        let (n_agents, obs_dim, act_dim, max_steps) = {
            let e = &slots[0];
            (e.n_agents(), e.obs_dim(), e.act_dim(), e.max_steps())
        };
        Ok(VecEnv { slots, n_agents, obs_dim, act_dim, max_steps })
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }
    pub fn n_agents(&self) -> usize {
        self.n_agents
    }
    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }
    pub fn act_dim(&self) -> usize {
        self.act_dim
    }
    pub fn max_steps(&self) -> usize {
        self.max_steps
    }

    /// Begin a new episode in one slot.
    pub fn reset_slot(&mut self, slot: usize) -> Vec<Vec<f32>> {
        self.slots[slot].reset()
    }

    /// Advance one slot by one step (no auto-reset — the caller owns
    /// the episode lifecycle).
    pub fn step_slot(&mut self, slot: usize, actions: &[usize]) -> Step {
        self.slots[slot].step(actions)
    }

    /// Begin a new episode in every slot; returns per-slot observations.
    pub fn reset_all(&mut self) -> Vec<Vec<Vec<f32>>> {
        self.slots.iter_mut().map(|e| e.reset()).collect()
    }

    /// Step every slot with its own action set.  Finished slots
    /// auto-reset: their [`SlotStep`] carries `done = true`, the
    /// episode's terminal observations in `final_obs`, and the fresh
    /// episode's first observations in `obs`.
    pub fn step_all(&mut self, actions: &[Vec<usize>]) -> Vec<SlotStep> {
        assert_eq!(actions.len(), self.slots.len(), "one action set per slot");
        self.slots
            .iter_mut()
            .zip(actions)
            .map(|(env, acts)| {
                let step = env.step(acts);
                if step.done {
                    let fresh = env.reset();
                    SlotStep {
                        obs: fresh,
                        rewards: step.rewards,
                        done: true,
                        info: step.info,
                        final_obs: Some(step.obs),
                    }
                } else {
                    SlotStep {
                        obs: step.obs,
                        rewards: step.rewards,
                        done: false,
                        info: step.info,
                        final_obs: None,
                    }
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_zero_keeps_base_seed_and_stream() {
        let mut v = VecEnv::make("pong2p", 3, 42).unwrap();
        let mut solo = make("pong2p", 42).unwrap();
        assert_eq!(v.reset_slot(0), solo.reset());
        for t in 0..30 {
            let acts: Vec<usize> =
                (0..v.n_agents()).map(|i| (t + i) % v.act_dim()).collect();
            let a = v.step_slot(0, &acts);
            let b = solo.step(&acts);
            assert_eq!(a.obs, b.obs, "diverged at {t}");
            assert_eq!(a.rewards, b.rewards);
            assert_eq!(a.done, b.done);
            if a.done {
                break;
            }
        }
    }

    #[test]
    fn slots_are_independently_seeded() {
        let mut v = VecEnv::make("synthetic:8", 4, 7).unwrap();
        assert_eq!(v.n_slots(), 4);
        let obs = v.reset_all();
        for i in 1..4 {
            assert_ne!(obs[0], obs[i], "slot {i} mirrors slot 0");
        }
        assert_eq!(slot_seed(7, 0), 7);
        assert_ne!(slot_seed(7, 1), slot_seed(7, 2));
        assert_ne!(slot_seed(7, 1), slot_seed(8, 1));
    }

    #[test]
    fn step_all_auto_resets_and_surfaces_boundaries() {
        let mut v = VecEnv::make("synthetic:3", 2, 1).unwrap();
        v.reset_all();
        for t in 0..3usize {
            let acts: Vec<Vec<usize>> =
                (0..2).map(|s| vec![s % 16, (s + t) % 16]).collect();
            let steps = v.step_all(&acts);
            for st in &steps {
                if t == 2 {
                    assert!(st.done, "3-step episode must end at step 3");
                    let fin =
                        st.final_obs.as_ref().expect("terminal obs surfaced");
                    assert_eq!(fin.len(), v.n_agents());
                    assert!(st.info.outcome.is_some());
                    // obs already belong to the auto-reset next episode
                    assert_eq!(st.obs.len(), v.n_agents());
                    assert_ne!(&st.obs, fin);
                } else {
                    assert!(!st.done);
                    assert!(st.final_obs.is_none());
                }
            }
        }
        // the auto-reset episodes keep stepping normally
        let steps = v.step_all(&vec![vec![0, 0]; 2]);
        assert!(steps.iter().all(|s| !s.done));
    }
}
