//! Synchronous ring-style allreduce for the multi-learner path.
//!
//! Substitutes the paper's Horovod/NCCL allreduce (§3.2): M_L learners
//! compute gradients on their own batches, average them, and apply the
//! same Adam step — keeping the replicas bit-identical ("strictly
//! synchronized", so only the rank-0 learner talks to the LeagueMgr).
//!
//! The implementation is a shared-memory reduce: participants deposit
//! their vector, the last arrival computes the mean, everyone leaves
//! with the result.  (A TCP ring is unnecessary at this repo's scale;
//! the module boundary is the same as Horovod's `allreduce(tensor)`.)

use std::sync::{Arc, Condvar, Mutex};

struct Slot {
    sum: Vec<f32>,
    arrived: usize,
    generation: u64,
    departed: usize,
    /// terminal: a participant died; every waiter must bail out
    poisoned: bool,
}

pub struct Allreduce {
    n: usize,
    slot: Mutex<Slot>,
    cv: Condvar,
}

impl Allreduce {
    pub fn new(n_participants: usize) -> Arc<Allreduce> {
        assert!(n_participants >= 1);
        Arc::new(Allreduce {
            n: n_participants,
            slot: Mutex::new(Slot {
                sum: Vec::new(),
                arrived: 0,
                generation: 0,
                departed: 0,
                poisoned: false,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn participants(&self) -> usize {
        self.n
    }

    /// Permanently wake every waiter and make all further reduces fail
    /// fast.  Called by a supervisor when a participant dies — without
    /// it, survivors blocked mid-generation wait for the missing rank
    /// forever and the teardown join deadlocks.  Terminal: the group's
    /// internal counters are left as-is, so a poisoned group must be
    /// discarded, never reused.
    pub fn poison(&self) {
        self.slot.lock().unwrap().poisoned = true;
        self.cv.notify_all();
    }

    /// Average `buf` across all participants (in place).  Blocks until
    /// every participant of this generation has arrived.  Returns false
    /// (with `buf` left unreduced/unspecified) if the group was
    /// poisoned — callers must treat that as a fatal step error.
    #[must_use]
    pub fn reduce(&self, buf: &mut [f32]) -> bool {
        if self.n == 1 {
            return true;
        }
        let mut slot = self.slot.lock().unwrap();
        // wait for the previous generation to fully drain
        while slot.departed != 0 {
            if slot.poisoned {
                return false;
            }
            slot = self.cv.wait(slot).unwrap();
        }
        if slot.poisoned {
            return false;
        }
        if slot.arrived == 0 {
            slot.sum.clear();
            slot.sum.extend_from_slice(buf);
        } else {
            assert_eq!(slot.sum.len(), buf.len(), "allreduce size mismatch");
            for (s, &x) in slot.sum.iter_mut().zip(buf.iter()) {
                *s += x;
            }
        }
        slot.arrived += 1;
        let my_gen = slot.generation;
        if slot.arrived == self.n {
            let inv = 1.0 / self.n as f32;
            for s in slot.sum.iter_mut() {
                *s *= inv;
            }
            slot.generation += 1;
            slot.departed = self.n;
            self.cv.notify_all();
        } else {
            while slot.generation == my_gen {
                if slot.poisoned {
                    return false;
                }
                slot = self.cv.wait(slot).unwrap();
            }
        }
        buf.copy_from_slice(&slot.sum);
        slot.arrived -= 1;
        slot.departed -= 1;
        if slot.departed == 0 {
            slot.arrived = 0;
            self.cv.notify_all();
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_participant_is_identity() {
        let ar = Allreduce::new(1);
        let mut v = vec![1.0, 2.0];
        assert!(ar.reduce(&mut v));
        assert_eq!(v, vec![1.0, 2.0]);
    }

    #[test]
    fn averages_across_participants() {
        let ar = Allreduce::new(4);
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let ar = ar.clone();
                std::thread::spawn(move || {
                    let mut v = vec![r as f32; 8];
                    assert!(ar.reduce(&mut v));
                    v
                })
            })
            .collect();
        for h in handles {
            let v = h.join().unwrap();
            assert_eq!(v, vec![1.5; 8], "mean of 0..4");
        }
    }

    /// Poison must wake a waiter blocked on missing peers (the dead-rank
    /// teardown path) and fail all later reduces fast.
    #[test]
    fn poison_unblocks_waiters_and_fails_fast() {
        let ar = Allreduce::new(2);
        let ar2 = ar.clone();
        let waiter = std::thread::spawn(move || {
            let mut v = vec![1.0];
            ar2.reduce(&mut v) // blocks: rank 1 never arrives
        });
        // give the waiter time to enter the generation wait
        std::thread::sleep(std::time::Duration::from_millis(50));
        ar.poison();
        assert!(!waiter.join().unwrap(), "poisoned reduce must return false");
        let mut v = vec![2.0];
        assert!(!ar.reduce(&mut v), "post-poison reduce must fail fast");
    }

    #[test]
    fn repeated_generations_stay_consistent() {
        let ar = Allreduce::new(3);
        let handles: Vec<_> = (0..3)
            .map(|r| {
                let ar = ar.clone();
                std::thread::spawn(move || {
                    let mut results = Vec::new();
                    for round in 0..50u32 {
                        let mut v = vec![(r as f32) + round as f32];
                        assert!(ar.reduce(&mut v));
                        results.push(v[0]);
                    }
                    results
                })
            })
            .collect();
        let all: Vec<Vec<f32>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for round in 0..50usize {
            let want = 1.0 + round as f32; // mean(0,1,2) + round
            for r in &all {
                assert_eq!(r[round], want, "round {round}");
            }
        }
    }
}
