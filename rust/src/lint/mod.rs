//! league-lint: project-invariant static analysis over `rust/src`.
//!
//! The repo carries hand-maintained invariants no generic tool checks —
//! a literal wire-tag registry, `unsafe` FFI blocks, epoll loop bodies
//! that must never block, and `.unwrap()` calls sitting on bytes that
//! arrive off the network.  This module is a zero-dependency rule
//! engine over a lightweight lexer (comments/strings blanked, no `syn`
//! — the offline crate set rule) that mechanically enforces them:
//!
//! * **proto-conformance** — in files marked `proto-registry` (see
//!   [`MARK_PROTO`]): `TAG_*` const values must be unique, every const
//!   must be written by `Msg::encode` and matched by a `Msg::decode`
//!   arm (and vice versa), and neither side may use a literal tag byte.
//! * **unsafe-safety** — every `unsafe` token must have a `// SAFETY:`
//!   comment on the same or one of the few preceding lines.
//! * **nonblocking** — a function annotated with the [`MARK_NONBLOCK`]
//!   marker may not call deny-listed blocking ops (`.lock()`,
//!   `thread::sleep`, `read_frame`, condvar waits, …) unless the line
//!   carries an explicit [`MARK_BLOCK_OK`] waiver with a reason.
//! * **unwrap-budget** — `.unwrap()`/`.expect()` in network-facing code
//!   (`transport/`, `model_pool/`, or files marked [`MARK_NETPATH`])
//!   is denied unless the file has a budgeted entry in
//!   `lint-allow.toml` (triage, not grandfathering: the budget is a
//!   ceiling, new calls past it fail CI).
//!
//! The binary (`cargo run --bin league-lint`) walks the tree and exits
//! nonzero on any finding; `--self-test rust/lint-fixtures` runs the
//! analyzer's own regression suite of seeded-bad snippets.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::path::Path;

pub const RULE_PROTO: &str = "proto-conformance";
pub const RULE_UNSAFE: &str = "unsafe-safety";
pub const RULE_NONBLOCK: &str = "nonblocking";
pub const RULE_UNWRAP: &str = "unwrap-budget";

// Markers are assembled with concat! so the lint never matches its own
// source when it scans itself as part of the tree walk.
/// Marks a file as a wire-tag registry (proto conformance applies).
pub const MARK_PROTO: &str = concat!("lint: proto", "-registry");
/// Marks the next `fn` as a nonblocking region.
pub const MARK_NONBLOCK: &str = concat!("lint: non", "blocking");
/// Per-line waiver inside a nonblocking region (give a reason).
pub const MARK_BLOCK_OK: &str = concat!("lint: blocking", "-ok");
/// Opts a file outside `transport/`/`model_pool/` into the unwrap rule.
pub const MARK_NETPATH: &str = concat!("lint: net", "path");
/// Per-line waiver for the unwrap rule (give a reason).
pub const MARK_UNWRAP_OK: &str = concat!("lint: unwrap", "-ok");
const MARK_SAFETY: &str = concat!("SAFETY", ":");

/// How many lines above an `unsafe` token a `SAFETY:` comment may sit
/// (allows `#[cfg(...)]` attributes between comment and block).
const SAFETY_LOOKBACK: usize = 6;

/// Ops a `nonblocking`-marked function must not call.
const BLOCKING_OPS: &[&str] = &[
    ".lock(",
    "lock_recover(",
    "thread::sleep",
    "read_frame",
    ".wait(",
    "wait_timeout",
    "recv_timeout",
    ".recv(",
    ".join(",
];

#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

// ---------------------------------------------------------------------------
// Lexer: blank comments and string/char literals so rules see code only.
// ---------------------------------------------------------------------------

/// Return `src` with comments and string/char literal *contents*
/// replaced by spaces, newlines preserved, so line/column structure
/// survives but tokens inside comments or strings can't match rules.
pub fn blank_noncode(src: &str) -> String {
    let ch: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    let n = ch.len();
    let blank = |out: &mut String, c: char| out.push(if c == '\n' { '\n' } else { ' ' });
    while i < n {
        let c = ch[i];
        // Line comment.
        if c == '/' && i + 1 < n && ch[i + 1] == '/' {
            while i < n && ch[i] != '\n' {
                blank(&mut out, ch[i]);
                i += 1;
            }
            continue;
        }
        // Block comment (Rust block comments nest).
        if c == '/' && i + 1 < n && ch[i + 1] == '*' {
            let mut depth = 0usize;
            while i < n {
                if ch[i] == '/' && i + 1 < n && ch[i + 1] == '*' {
                    depth += 1;
                    blank(&mut out, ch[i]);
                    blank(&mut out, ch[i + 1]);
                    i += 2;
                } else if ch[i] == '*' && i + 1 < n && ch[i + 1] == '/' {
                    depth -= 1;
                    blank(&mut out, ch[i]);
                    blank(&mut out, ch[i + 1]);
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    blank(&mut out, ch[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"..." / r#"..."# (optionally b-prefixed).
        if (c == 'r' || (c == 'b' && i + 1 < n && ch[i + 1] == 'r'))
            && !prev_is_ident(&ch, i)
        {
            let r_at = if c == 'b' { i + 1 } else { i };
            let mut j = r_at + 1;
            let mut hashes = 0usize;
            while j < n && ch[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && ch[j] == '"' {
                // Blank through the closing quote + matching hashes.
                while i <= j {
                    blank(&mut out, ch[i]);
                    i += 1;
                }
                'raw: while i < n {
                    if ch[i] == '"' {
                        let mut k = 0usize;
                        while k < hashes && i + 1 + k < n && ch[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                blank(&mut out, ch[i]);
                                i += 1;
                            }
                            break 'raw;
                        }
                    }
                    blank(&mut out, ch[i]);
                    i += 1;
                }
                continue;
            }
        }
        // Plain string literal.
        if c == '"' {
            blank(&mut out, c);
            i += 1;
            while i < n {
                if ch[i] == '\\' && i + 1 < n {
                    blank(&mut out, ch[i]);
                    blank(&mut out, ch[i + 1]);
                    i += 2;
                    continue;
                }
                let done = ch[i] == '"';
                blank(&mut out, ch[i]);
                i += 1;
                if done {
                    break;
                }
            }
            continue;
        }
        // Char literal vs lifetime: 'x' or '\n' is a literal; 'a (no
        // closing quote right after) is a lifetime and passes through.
        if c == '\'' {
            let is_char = if i + 1 < n && ch[i + 1] == '\\' {
                true
            } else {
                i + 2 < n && ch[i + 2] == '\''
            };
            if is_char {
                blank(&mut out, c);
                i += 1;
                while i < n {
                    if ch[i] == '\\' && i + 1 < n {
                        blank(&mut out, ch[i]);
                        blank(&mut out, ch[i + 1]);
                        i += 2;
                        continue;
                    }
                    let done = ch[i] == '\'';
                    blank(&mut out, ch[i]);
                    i += 1;
                    if done {
                        break;
                    }
                }
                continue;
            }
        }
        out.push(c);
        i += 1;
    }
    out
}

fn prev_is_ident(ch: &[char], i: usize) -> bool {
    i > 0 && (ch[i - 1].is_alphanumeric() || ch[i - 1] == '_')
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Does `line` contain `word` with non-identifier characters around it?
fn has_word(line: &str, word: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = line[from..].find(word) {
        let at = from + pos;
        let before_ok = at == 0 || !line[..at].chars().next_back().is_some_and(is_ident_char);
        let after_ok = !line[at + word.len()..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            return true;
        }
        from = at + word.len();
    }
    false
}

/// One source file, pre-lexed: raw lines plus comment/string-blanked
/// code lines (same line count).
pub struct SrcFile {
    pub rel: String,
    pub raw: Vec<String>,
    pub code: Vec<String>,
}

impl SrcFile {
    pub fn parse(rel: &str, src: &str) -> SrcFile {
        let code = blank_noncode(src);
        SrcFile {
            rel: rel.to_string(),
            raw: src.lines().map(str::to_string).collect(),
            code: code.lines().map(str::to_string).collect(),
        }
    }

    fn finding(&self, line0: usize, rule: &'static str, msg: String) -> Finding {
        Finding { file: self.rel.clone(), line: line0 + 1, rule, msg }
    }
}

// ---------------------------------------------------------------------------
// Allowlist (restricted TOML: [[allow]] tables with file/budget/reason).
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub budget: usize,
    pub reason: String,
}

#[derive(Debug, Default)]
pub struct Allowlist {
    entries: HashMap<String, AllowEntry>,
}

impl Allowlist {
    pub fn empty() -> Allowlist {
        Allowlist::default()
    }

    pub fn get(&self, rel: &str) -> Option<&AllowEntry> {
        self.entries.get(rel)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Parse the `lint-allow.toml` format: `[[allow]]` tables with
    /// `file = "…"`, `budget = N`, `reason = "…"` keys.  Hand-rolled
    /// (no toml crate offline); rejects unknown keys and duplicates so
    /// typos fail loudly instead of silently allowing everything.
    pub fn parse(text: &str) -> Result<Allowlist, String> {
        #[derive(Default)]
        struct Partial {
            file: Option<String>,
            budget: Option<usize>,
            reason: Option<String>,
        }
        fn flush(
            cur: &mut Option<Partial>,
            entries: &mut HashMap<String, AllowEntry>,
        ) -> Result<(), String> {
            if let Some(p) = cur.take() {
                let file = p.file.ok_or("allow entry missing `file`")?;
                let budget =
                    p.budget.ok_or_else(|| format!("entry '{file}' missing `budget`"))?;
                let reason =
                    p.reason.ok_or_else(|| format!("entry '{file}' missing `reason`"))?;
                if entries.insert(file.clone(), AllowEntry { budget, reason }).is_some() {
                    return Err(format!("duplicate allow entry for '{file}'"));
                }
            }
            Ok(())
        }
        let mut entries = HashMap::new();
        let mut cur: Option<Partial> = None;
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "[[allow]]" {
                flush(&mut cur, &mut entries)?;
                cur = Some(Partial::default());
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", ln + 1))?;
            let slot = cur
                .as_mut()
                .ok_or_else(|| format!("line {}: key outside [[allow]] table", ln + 1))?;
            let val = val.trim();
            match key.trim() {
                "file" => slot.file = Some(unquote(val, ln)?),
                "budget" => {
                    slot.budget = Some(
                        val.parse::<usize>()
                            .map_err(|_| format!("line {}: bad budget '{val}'", ln + 1))?,
                    )
                }
                "reason" => slot.reason = Some(unquote(val, ln)?),
                other => return Err(format!("line {}: unknown key '{other}'", ln + 1)),
            }
        }
        flush(&mut cur, &mut entries)?;
        Ok(Allowlist { entries })
    }

    pub fn load(path: &Path) -> Result<Allowlist, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Allowlist::parse(&text)
    }
}

fn unquote(v: &str, ln: usize) -> Result<String, String> {
    let v = v.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        Ok(v[1..v.len() - 1].to_string())
    } else {
        Err(format!("line {}: expected quoted string, got '{v}'", ln + 1))
    }
}

// ---------------------------------------------------------------------------
// Rule: unsafe hygiene.
// ---------------------------------------------------------------------------

fn check_unsafe(f: &SrcFile, out: &mut Vec<Finding>) {
    for i in 0..f.code.len() {
        if !has_word(&f.code[i], "unsafe") {
            continue;
        }
        let lo = i.saturating_sub(SAFETY_LOOKBACK);
        let documented = (lo..=i).any(|j| f.raw[j].contains(MARK_SAFETY));
        if !documented {
            out.push(f.finding(
                i,
                RULE_UNSAFE,
                format!(
                    "`unsafe` without a `// {MARK_SAFETY}` comment on this or one of the \
                     {SAFETY_LOOKBACK} preceding lines"
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: nonblocking regions.
// ---------------------------------------------------------------------------

fn brace_delta(line: &str) -> (i32, i32) {
    // (opens, closes) on a code line (strings already blanked).
    let opens = line.matches('{').count() as i32;
    let closes = line.matches('}').count() as i32;
    (opens, closes)
}

fn check_nonblocking(f: &SrcFile, out: &mut Vec<Finding>) {
    let n = f.raw.len();
    let mut i = 0;
    while i < n {
        if !f.raw[i].contains(MARK_NONBLOCK) {
            i += 1;
            continue;
        }
        // The marker must sit directly above a fn (attributes and doc
        // comments between are fine, within a small window).
        let mut j = i + 1;
        let mut fn_line = None;
        while j < n && j <= i + 10 {
            if has_word(&f.code[j], "fn") {
                fn_line = Some(j);
                break;
            }
            j += 1;
        }
        let Some(fn_line) = fn_line else {
            out.push(f.finding(
                i,
                RULE_NONBLOCK,
                format!("dangling `{MARK_NONBLOCK}` marker: no fn within 10 lines"),
            ));
            i += 1;
            continue;
        };
        // Find the body: first '{' at/after the fn line, then walk to
        // its matching close.
        let mut depth = 0i32;
        let mut started = false;
        let mut k = fn_line;
        while k < n {
            let (o, c) = brace_delta(&f.code[k]);
            if !started && o > 0 {
                started = true;
            }
            if started {
                // Inside the body (lines after the opener, and the
                // remainder of opener/closer lines) check deny list.
                if depth > 0 || o > 0 {
                    check_blocking_line(f, k, out);
                }
                depth += o - c;
                if depth <= 0 {
                    break;
                }
            }
            k += 1;
        }
        i = k + 1;
    }
}

/// `op` occurrence with identifier-boundary checks on whichever of its
/// edges are identifier characters — `read_frame` must not match a fn
/// *named* `try_read_frame`, while `.lock(` still matches `q.lock()`.
fn contains_op(line: &str, op: &str) -> bool {
    let start_ident = op.chars().next().is_some_and(is_ident_char);
    let end_ident = op.chars().next_back().is_some_and(is_ident_char);
    let mut from = 0;
    while let Some(pos) = line[from..].find(op) {
        let at = from + pos;
        let before_ok =
            !start_ident || !line[..at].chars().next_back().is_some_and(is_ident_char);
        let after_ok =
            !end_ident || !line[at + op.len()..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            return true;
        }
        from = at + op.len();
    }
    false
}

fn check_blocking_line(f: &SrcFile, k: usize, out: &mut Vec<Finding>) {
    for op in BLOCKING_OPS {
        if contains_op(&f.code[k], op) && !f.raw[k].contains(MARK_BLOCK_OK) {
            out.push(f.finding(
                k,
                RULE_NONBLOCK,
                format!(
                    "blocking op `{op}` inside a `{MARK_NONBLOCK}` region \
                     (waive with `// {MARK_BLOCK_OK}: <reason>` if provably bounded)"
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: unwrap budget on network/cross-process paths.
// ---------------------------------------------------------------------------

fn unwrap_in_scope(f: &SrcFile) -> bool {
    f.rel.starts_with("transport/")
        || f.rel.starts_with("model_pool/")
        || f.raw.iter().any(|l| l.contains(MARK_NETPATH))
}

/// Mark lines inside `#[cfg(test)] mod …` regions (tests may unwrap
/// freely — a test panic is the desired failure mode).
fn test_region_mask(f: &SrcFile) -> Vec<bool> {
    let n = f.code.len();
    let mut mask = vec![false; n];
    let mut i = 0;
    while i < n {
        if !f.raw[i].contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // Find the mod line within a couple of lines, then its region.
        let mut j = i + 1;
        while j < n && j <= i + 3 && !has_word(&f.code[j], "mod") {
            j += 1;
        }
        if j >= n || !has_word(&f.code[j], "mod") {
            i += 1;
            continue;
        }
        let mut depth = 0i32;
        let mut started = false;
        let mut k = j;
        while k < n {
            let (o, c) = brace_delta(&f.code[k]);
            if !started && o > 0 {
                started = true;
            }
            mask[k] = true;
            if started {
                depth += o - c;
                if depth <= 0 {
                    break;
                }
            }
            k += 1;
        }
        i = k + 1;
    }
    mask
}

fn check_unwrap(f: &SrcFile, allow: &Allowlist, out: &mut Vec<Finding>) {
    let mask = test_region_mask(f);
    let mut hits: Vec<usize> = Vec::new();
    for (i, code) in f.code.iter().enumerate() {
        if mask[i] || f.raw[i].contains(MARK_UNWRAP_OK) {
            continue;
        }
        let count = code.matches(".unwrap()").count() + code.matches(".expect(").count();
        for _ in 0..count {
            hits.push(i);
        }
    }
    if hits.is_empty() {
        return;
    }
    let first = hits[0];
    match allow.get(&f.rel) {
        None => out.push(f.finding(
            first,
            RULE_UNWRAP,
            format!(
                "{} .unwrap()/.expect() call(s) on a network/cross-process path with no \
                 lint-allow.toml entry for '{}'",
                hits.len(),
                f.rel
            ),
        )),
        Some(entry) if hits.len() > entry.budget => out.push(f.finding(
            first,
            RULE_UNWRAP,
            format!(
                "{} .unwrap()/.expect() call(s) exceed the allowlisted budget of {} for \
                 '{}' — handle the error or raise the budget with a reason",
                hits.len(),
                entry.budget,
                f.rel
            ),
        )),
        Some(_) => {}
    }
}

// ---------------------------------------------------------------------------
// Rule: proto conformance (tag registry files).
// ---------------------------------------------------------------------------

/// Parse the `TAG_*` const table out of (already-lexed or raw) proto
/// source: `(name, value, line0)` triples in declaration order.
fn parse_tag_consts(code: &[String]) -> Result<Vec<(String, u8, usize)>, String> {
    let mut tags = Vec::new();
    for (i, line) in code.iter().enumerate() {
        let Some(pos) = line.find("const TAG_") else { continue };
        let rest = &line[pos + "const ".len()..];
        let name_end = rest.find(':').ok_or_else(|| format!("line {}: malformed const", i + 1))?;
        let name = rest[..name_end].trim().to_string();
        let eq = rest.find('=').ok_or_else(|| format!("line {}: const without value", i + 1))?;
        let val = rest[eq + 1..].trim().trim_end_matches(';').trim();
        let value: u8 = val
            .parse()
            .map_err(|_| {
                format!("line {}: tag const {name} has non-literal value '{val}'", i + 1)
            })?;
        tags.push((name, value, i));
    }
    Ok(tags)
}

/// Public tag-table API for cross-checking tests: `(name, value)` pairs
/// from `src`, or an error if the table is malformed.
pub fn proto_tag_table(src: &str) -> Result<Vec<(String, u8)>, String> {
    let code: Vec<String> = blank_noncode(src).lines().map(str::to_string).collect();
    let tags = parse_tag_consts(&code)?;
    Ok(tags.into_iter().map(|(n, v, _)| (n, v)).collect())
}

/// Locate the body line range (start..=end, body lines only) of the
/// first `needle` at/after `from`, by brace matching.
fn body_of(code: &[String], from: usize, needle: &str) -> Option<(usize, usize)> {
    let n = code.len();
    let mut at = from;
    while at < n && !code[at].contains(needle) {
        at += 1;
    }
    if at >= n {
        return None;
    }
    let mut depth = 0i32;
    let mut started = false;
    let mut k = at;
    while k < n {
        let (o, c) = brace_delta(&code[k]);
        if !started && o > 0 {
            started = true;
        }
        if started {
            depth += o - c;
            if depth <= 0 {
                return Some((at, k));
            }
        }
        k += 1;
    }
    None
}

fn check_proto(f: &SrcFile, out: &mut Vec<Finding>) {
    let tags = match parse_tag_consts(&f.code) {
        Ok(t) => t,
        Err(e) => {
            out.push(f.finding(0, RULE_PROTO, e));
            return;
        }
    };
    let mut by_value: HashMap<u8, &str> = HashMap::new();
    for (name, value, line) in &tags {
        if let Some(prev) = by_value.insert(*value, name) {
            out.push(f.finding(
                *line,
                RULE_PROTO,
                format!("duplicate wire tag {value}: {name} collides with {prev}"),
            ));
        }
    }
    let names: HashSet<&str> = tags.iter().map(|(n, _, _)| n.as_str()).collect();

    let Some((impl_at, impl_end)) = body_of(&f.code, 0, "impl Wire for Msg") else {
        out.push(f.finding(
            0,
            RULE_PROTO,
            "proto-registry file without an `impl Wire for Msg` block".into(),
        ));
        return;
    };

    // Encode side: every put_u8(TAG_*) collects; put_u8(<integer>) is a
    // literal tag byte and always a violation inside Msg::encode.
    let mut encoded: HashMap<String, usize> = HashMap::new();
    if let Some((enc_at, enc_end)) = body_of(&f.code, impl_at, "fn encode") {
        for i in enc_at..=enc_end.min(impl_end) {
            let code = &f.code[i];
            let mut from = 0;
            while let Some(pos) = code[from..].find("put_u8(") {
                let at = from + pos + "put_u8(".len();
                let Some(close) = code[at..].find(')') else { break };
                let arg = code[at..at + close].trim();
                if !arg.is_empty() && arg.chars().all(|c| c.is_ascii_digit()) {
                    out.push(f.finding(
                        i,
                        RULE_PROTO,
                        format!("literal tag byte {arg} in Msg::encode — use a TAG_* const"),
                    ));
                } else if arg.starts_with("TAG_") {
                    encoded.entry(arg.to_string()).or_insert(i);
                }
                from = at + close;
            }
        }
    } else {
        out.push(f.finding(impl_at, RULE_PROTO, "impl Wire for Msg without fn encode".into()));
    }

    // Decode side: arms of the `match tag` block at depth 1 must be
    // TAG_* idents (or a lowercase fallback binding), never literals.
    let mut decoded: HashMap<String, usize> = HashMap::new();
    if let Some((dec_at, dec_end)) = body_of(&f.code, impl_at, "fn decode") {
        if let Some((match_at, match_end)) = body_of(&f.code, dec_at, "match tag") {
            let mut depth = 0i32;
            for i in match_at..=match_end.min(dec_end) {
                let at_arm_depth = depth == 1;
                let (o, c) = brace_delta(&f.code[i]);
                depth += o - c;
                let trimmed = f.code[i].trim();
                let is_arm = (at_arm_depth || i == match_at) && trimmed.contains("=>");
                if !is_arm || i == match_at {
                    continue;
                }
                let head = trimmed.split("=>").next().unwrap_or("").trim();
                if !head.is_empty() && head.chars().all(|c| c.is_ascii_digit()) {
                    out.push(f.finding(
                        i,
                        RULE_PROTO,
                        format!("literal tag {head} in Msg::decode arm — use a TAG_* const"),
                    ));
                } else if head.starts_with("TAG_") {
                    decoded.entry(head.to_string()).or_insert(i);
                }
            }
        } else {
            out.push(f.finding(dec_at, RULE_PROTO, "fn decode without a `match tag` block".into()));
        }
    } else {
        out.push(f.finding(impl_at, RULE_PROTO, "impl Wire for Msg without fn decode".into()));
    }

    // Symmetry: const table == encode set == decode set.
    for (name, _, line) in &tags {
        if !encoded.contains_key(name.as_str()) {
            out.push(f.finding(
                *line,
                RULE_PROTO,
                format!("{name} declared but never written by Msg::encode"),
            ));
        }
        if !decoded.contains_key(name.as_str()) {
            out.push(f.finding(
                *line,
                RULE_PROTO,
                format!("{name} declared but has no Msg::decode arm"),
            ));
        }
    }
    for (name, line) in &encoded {
        if !names.contains(name.as_str()) {
            out.push(f.finding(
                *line,
                RULE_PROTO,
                format!("{name} written by Msg::encode but not in the tag const table"),
            ));
        }
    }
    for (name, line) in &decoded {
        if !names.contains(name.as_str()) {
            out.push(f.finding(
                *line,
                RULE_PROTO,
                format!("{name} matched by Msg::decode but not in the tag const table"),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// Drivers.
// ---------------------------------------------------------------------------

/// Lint one file (path shown as `rel`, which also selects path-scoped
/// rules like the transport unwrap budget).
pub fn lint_file(rel: &str, src: &str, allow: &Allowlist) -> Vec<Finding> {
    let f = SrcFile::parse(rel, src);
    let mut out = Vec::new();
    check_unsafe(&f, &mut out);
    check_nonblocking(&f, &mut out);
    if unwrap_in_scope(&f) {
        check_unwrap(&f, allow, &mut out);
    }
    if f.raw.iter().any(|l| l.contains(MARK_PROTO)) {
        check_proto(&f, &mut out);
    }
    out
}

fn walk(dir: &Path, files: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?
        .collect::<Result<_, _>>()
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    entries.sort_by_key(|e| e.path());
    for ent in entries {
        let p = ent.path();
        if p.is_dir() {
            walk(&p, files)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            files.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (rel paths computed against it).
/// Returns findings plus the number of (files, bytes) scanned.
pub fn lint_tree(root: &Path, allow: &Allowlist) -> Result<(Vec<Finding>, usize, u64), String> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    let mut out = Vec::new();
    let mut bytes = 0u64;
    for p in &files {
        let rel = p
            .strip_prefix(root)
            .map_err(|e| format!("strip_prefix: {e}"))?
            .to_string_lossy()
            .replace('\\', "/");
        let src =
            std::fs::read_to_string(p).map_err(|e| format!("read {}: {e}", p.display()))?;
        bytes += src.len() as u64;
        out.extend(lint_file(&rel, &src, allow));
    }
    Ok((out, files.len(), bytes))
}

/// The analyzer's own regression suite: every fixture under `dir` named
/// `<rule>__<desc>.rs` must produce at least one finding of that rule
/// (prefix `clean` must produce none).  Fixtures are linted with an
/// empty allowlist and opt into scoped rules via markers.
pub fn self_test(dir: &Path) -> Result<String, String> {
    let mut files = Vec::new();
    walk(dir, &mut files)?;
    if files.is_empty() {
        return Err(format!("no fixtures under {}", dir.display()));
    }
    let allow = Allowlist::empty();
    let mut failures = Vec::new();
    let mut checked = 0usize;
    for p in &files {
        let name = p.file_stem().map(|s| s.to_string_lossy().to_string()).unwrap_or_default();
        let prefix = name.split("__").next().unwrap_or("").to_string();
        let want: Option<&'static str> = match prefix.as_str() {
            "clean" => None,
            "proto" => Some(RULE_PROTO),
            "unsafe" => Some(RULE_UNSAFE),
            "nonblocking" => Some(RULE_NONBLOCK),
            "unwrap" => Some(RULE_UNWRAP),
            other => {
                failures.push(format!("{name}.rs: unknown fixture prefix '{other}'"));
                continue;
            }
        };
        let src =
            std::fs::read_to_string(p).map_err(|e| format!("read {}: {e}", p.display()))?;
        let findings = lint_file(&format!("fixtures/{name}.rs"), &src, &allow);
        checked += 1;
        match want {
            None => {
                if !findings.is_empty() {
                    failures.push(format!(
                        "{name}.rs: expected clean, got {} finding(s): {}",
                        findings.len(),
                        findings[0]
                    ));
                }
            }
            Some(rule) => {
                if !findings.iter().any(|f| f.rule == rule) {
                    failures.push(format!(
                        "{name}.rs: expected a [{rule}] finding, got {:?}",
                        findings.iter().map(|f| f.rule).collect::<Vec<_>>()
                    ));
                }
            }
        }
    }
    if failures.is_empty() {
        Ok(format!("self-test OK: {checked} fixture(s) behaved as seeded"))
    } else {
        Err(failures.join("\n"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_str(rel: &str, src: &str) -> Vec<Finding> {
        lint_file(rel, src, &Allowlist::empty())
    }

    #[test]
    fn lexer_blanks_comments_and_strings() {
        let src = "let a = \"unsafe\"; // unsafe here\nlet b = 'x'; /* .lock() */ let c = 1;";
        let out = blank_noncode(src);
        assert!(!out.contains("unsafe"));
        assert!(!out.contains(".lock()"));
        assert!(out.contains("let a ="));
        assert!(out.contains("let c = 1;"));
        assert_eq!(out.lines().count(), src.lines().count());
    }

    #[test]
    fn lexer_keeps_lifetimes() {
        let out = blank_noncode("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(out.contains("'a str"));
    }

    #[test]
    fn lexer_handles_raw_strings() {
        let out = blank_noncode("let s = r#\"unsafe { \" } \"#; let t = 2;");
        assert!(!out.contains("unsafe"));
        assert!(out.contains("let t = 2;"));
    }

    #[test]
    fn unsafe_without_safety_flags() {
        let src = "fn f() {\n    unsafe { g(); }\n}\n";
        let got = lint_str("x.rs", src);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].rule, RULE_UNSAFE);
        assert_eq!(got[0].line, 2);
    }

    #[test]
    fn unsafe_with_nearby_safety_passes() {
        let src = format!(
            "fn f() {{\n    // {MARK_SAFETY} fd is owned\n    #[cfg(unix)]\n    \
             unsafe {{ g(); }}\n}}\n"
        );
        assert!(lint_str("x.rs", &src).is_empty());
    }

    #[test]
    fn unsafe_in_comment_ignored() {
        let src = "// this mentions unsafe code\nfn f() {}\n";
        assert!(lint_str("x.rs", src).is_empty());
    }

    #[test]
    fn nonblocking_region_denies_lock() {
        let src = format!(
            "// {MARK_NONBLOCK}\nfn pump(&mut self) {{\n    let g = self.q.lock();\n}}\n"
        );
        let got = lint_str("x.rs", &src);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].rule, RULE_NONBLOCK);
    }

    #[test]
    fn nonblocking_waiver_passes() {
        let src = format!(
            "// {MARK_NONBLOCK}\nfn pump(&mut self) {{\n    let g = self.q.lock(); \
             // {MARK_BLOCK_OK}: sub-us critical section\n}}\n"
        );
        assert!(lint_str("x.rs", &src).is_empty());
    }

    #[test]
    fn nonblocking_op_needs_ident_boundary() {
        // A fn *named* try_read_frame is not a call to read_frame…
        let ok = format!(
            "// {MARK_NONBLOCK}\nfn try_read_frame(&self) -> Result<bool> {{\n    \
             Ok(false)\n}}\n"
        );
        assert!(lint_str("x.rs", &ok).is_empty());
        // …but an actual read_frame call inside the region is.
        let bad = format!(
            "// {MARK_NONBLOCK}\nfn pump(&mut self) {{\n    read_frame(s, buf)?;\n}}\n"
        );
        let got = lint_str("x.rs", &bad);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].rule, RULE_NONBLOCK);
    }

    #[test]
    fn nonblocking_scope_ends_at_fn_close() {
        let src = format!(
            "// {MARK_NONBLOCK}\nfn pump() {{\n    let x = 1;\n}}\n\nfn other() {{\n    \
             std::thread::sleep(d);\n}}\n"
        );
        assert!(lint_str("x.rs", &src).is_empty());
    }

    #[test]
    fn unwrap_on_netpath_needs_listing() {
        let src = "fn f(b: &[u8]) {\n    let m = Msg::from_bytes(b).unwrap();\n}\n";
        let got = lint_str("transport/x.rs", src);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].rule, RULE_UNWRAP);
        // Same file outside the scoped paths: no finding.
        assert!(lint_str("league/x.rs", src).is_empty());
    }

    #[test]
    fn unwrap_budget_is_a_ceiling() {
        let allow = Allowlist::parse(
            "[[allow]]\nfile = \"transport/x.rs\"\nbudget = 1\nreason = \"t\"\n",
        )
        .unwrap();
        let one = "fn f() {\n    a().unwrap();\n}\n";
        let two = "fn f() {\n    a().unwrap();\n    b().expect(\"x\");\n}\n";
        assert!(lint_file("transport/x.rs", one, &allow).is_empty());
        let got = lint_file("transport/x.rs", two, &allow);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].rule, RULE_UNWRAP);
    }

    #[test]
    fn unwrap_in_test_mod_ignored() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
                   a().unwrap();\n    }\n}\n";
        assert!(lint_str("transport/x.rs", src).is_empty());
    }

    const PROTO_OK: &str = "\
pub const TAG_A: u8 = 1;
pub const TAG_B: u8 = 2;
impl Wire for Msg {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Msg::A => buf.put_u8(TAG_A),
            Msg::B(x) => {
                buf.put_u8(TAG_B);
                buf.put_u32(*x);
            }
        }
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        let tag = cur.u8()?;
        Ok(match tag {
            TAG_A => Msg::A,
            TAG_B => Msg::B(cur.u32()?),
            t => bail!(\"unknown tag {t}\"),
        })
    }
}
";

    fn with_marker(src: &str) -> String {
        format!("// {MARK_PROTO}\n{src}")
    }

    #[test]
    fn proto_conformant_registry_passes() {
        let got = lint_str("proto/mod.rs", &with_marker(PROTO_OK));
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn proto_duplicate_tag_flags() {
        let src = with_marker(&PROTO_OK.replace("TAG_B: u8 = 2", "TAG_B: u8 = 1"));
        let got = lint_str("proto/mod.rs", &src);
        assert!(got.iter().any(|f| f.rule == RULE_PROTO && f.msg.contains("duplicate")));
    }

    #[test]
    fn proto_missing_decode_arm_flags() {
        let src = with_marker(&PROTO_OK.replace("            TAG_B => Msg::B(cur.u32()?),\n", ""));
        let got = lint_str("proto/mod.rs", &src);
        assert!(
            got.iter().any(|f| f.rule == RULE_PROTO && f.msg.contains("no Msg::decode arm")),
            "{got:?}"
        );
    }

    #[test]
    fn proto_literal_tag_flags() {
        let src = with_marker(&PROTO_OK.replace("buf.put_u8(TAG_A)", "buf.put_u8(1)"));
        let got = lint_str("proto/mod.rs", &src);
        assert!(
            got.iter().any(|f| f.rule == RULE_PROTO && f.msg.contains("literal tag byte")),
            "{got:?}"
        );
    }

    #[test]
    fn proto_literal_decode_arm_flags() {
        let src = with_marker(
            &PROTO_OK.replace("            TAG_A => Msg::A,", "            1 => Msg::A,"),
        );
        let got = lint_str("proto/mod.rs", &src);
        assert!(
            got.iter().any(|f| f.rule == RULE_PROTO && f.msg.contains("literal tag 1")),
            "{got:?}"
        );
    }

    #[test]
    fn proto_tag_table_parses() {
        let t = proto_tag_table(PROTO_OK).unwrap();
        assert_eq!(t, vec![("TAG_A".to_string(), 1), ("TAG_B".to_string(), 2)]);
    }

    #[test]
    fn allowlist_rejects_malformed() {
        assert!(Allowlist::parse("[[allow]]\nbudget = 3\nreason = \"x\"\n").is_err());
        assert!(Allowlist::parse("file = \"a\"\n").is_err());
        assert!(Allowlist::parse("[[allow]]\nfile = \"a\"\nbudget = x\nreason = \"r\"\n").is_err());
        let dup = "[[allow]]\nfile = \"a\"\nbudget = 1\nreason = \"r\"\n\
                   [[allow]]\nfile = \"a\"\nbudget = 2\nreason = \"r\"\n";
        assert!(Allowlist::parse(dup).is_err());
    }
}
