//! Durable league state: versioned snapshots + restore (paper §3.2).
//!
//! "The LeagueMgr ... saves checkpoints, including the model parameters
//! and the payoff matrix" — week-long CSP runs must survive preemption.
//! This module owns the on-disk format: a [`LeagueSnapshot`] captures the
//! complete league (payoff matrix + Elo, frozen-pool order, current
//! learner keys, HyperMgr tables + PBT RNG, the LeagueMgr RNG stream,
//! episode/frame/task counters, and every ModelPool blob) as one
//! `util::codec` Wire blob, and a [`CheckpointMgr`] persists numbered
//! snapshots with write-temp-then-atomic-rename semantics, retaining the
//! last K.  Restore is bit-exact: encoding a restored snapshot yields the
//! same bytes that were loaded (see DESIGN.md §Checkpointing).
//!
//! The ModelPool's disk-spill files (cold frozen blobs under an LRU byte
//! budget, see `model_pool`) use the same `ModelBlob` wire encoding and
//! live in `spill-*/` subdirectories next to the snapshots.

use crate::league::hyper::HyperMgr;
use crate::league::payoff::PayoffMatrix;
use crate::proto::{ModelBlob, ModelKey};
use crate::util::codec::{Cursor, Enc, Wire};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// "TLCK" — tags every snapshot file.
pub const SNAP_MAGIC: u32 = 0x544c_434b;
/// Bump when the snapshot layout changes; decoders reject other versions.
pub const SNAP_FORMAT: u32 = 1;

/// Complete durable league state.  `models` holds every ModelPool blob
/// (the LeagueMgr-side fields never reference parameters directly, so the
/// pool contents ride along explicitly).
#[derive(Clone)]
pub struct LeagueSnapshot {
    /// frozen models in freeze order (the opponent pool M)
    pub pool: Vec<ModelKey>,
    /// per-agent current learner keys
    pub current: Vec<ModelKey>,
    pub next_task: u64,
    pub episodes: u64,
    pub frames: u64,
    pub n_opponents: u32,
    /// GameMgr sampler name (rebuilt by name on restore)
    pub game_mgr: String,
    /// LeagueMgr RNG stream (state, inc)
    pub rng: (u64, u64),
    pub payoff: PayoffMatrix,
    pub hyper: HyperMgr,
    pub models: Vec<ModelBlob>,
}

impl Wire for LeagueSnapshot {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u32(SNAP_MAGIC);
        buf.put_u32(SNAP_FORMAT);
        buf.put_u32(self.pool.len() as u32);
        for k in &self.pool {
            k.encode(buf);
        }
        buf.put_u32(self.current.len() as u32);
        for k in &self.current {
            k.encode(buf);
        }
        buf.put_u64(self.next_task);
        buf.put_u64(self.episodes);
        buf.put_u64(self.frames);
        buf.put_u32(self.n_opponents);
        buf.put_str(&self.game_mgr);
        buf.put_u64(self.rng.0);
        buf.put_u64(self.rng.1);
        self.payoff.encode(buf);
        self.hyper.encode(buf);
        buf.put_u32(self.models.len() as u32);
        for b in &self.models {
            b.encode(buf);
        }
    }

    fn decode(cur: &mut Cursor) -> Result<Self> {
        let magic = cur.u32()?;
        if magic != SNAP_MAGIC {
            bail!("not a league snapshot (magic {magic:#010x})");
        }
        let format = cur.u32()?;
        if format != SNAP_FORMAT {
            bail!("snapshot format {format} unsupported (want {SNAP_FORMAT})");
        }
        let n_pool = cur.u32()? as usize;
        let pool: Vec<ModelKey> =
            (0..n_pool).map(|_| ModelKey::decode(cur)).collect::<Result<_>>()?;
        let n_cur = cur.u32()? as usize;
        let current: Vec<ModelKey> =
            (0..n_cur).map(|_| ModelKey::decode(cur)).collect::<Result<_>>()?;
        let next_task = cur.u64()?;
        let episodes = cur.u64()?;
        let frames = cur.u64()?;
        let n_opponents = cur.u32()?;
        let game_mgr = cur.str()?;
        let rng = (cur.u64()?, cur.u64()?);
        let payoff = PayoffMatrix::decode(cur)?;
        let hyper = HyperMgr::decode(cur)?;
        let n_models = cur.u32()? as usize;
        let models: Vec<ModelBlob> =
            (0..n_models).map(|_| ModelBlob::decode(cur)).collect::<Result<_>>()?;
        Ok(LeagueSnapshot {
            pool,
            current,
            next_task,
            episodes,
            frames,
            n_opponents,
            game_mgr,
            rng,
            payoff,
            hyper,
            models,
        })
    }
}

/// Merge per-shard blob dumps into one deduplicated, sorted model list —
/// the snapshotter's aggregation step for sharded pools, where no single
/// replica holds everything.  Replicated copies of a key are identical
/// by construction (owner-only writes + anti-entropy), so the first one
/// seen wins; keys are deduplicated by `(agent, version)` and the result
/// is sorted so snapshot bytes stay deterministic across shard layouts.
pub fn merge_shard_models(shards: Vec<Vec<ModelBlob>>) -> Vec<ModelBlob> {
    let mut all: Vec<ModelBlob> = shards.into_iter().flatten().collect();
    all.sort_by_key(|b| b.key);
    all.dedup_by(|a, b| a.key == b.key);
    all
}

/// Numbered snapshots in one directory: `snap-00000042.tlc`.  Writes go
/// to a dotfile first and are atomically renamed into place, so readers
/// (and a crash mid-write) never observe a torn snapshot; after each save
/// everything but the newest `keep` snapshots is pruned.
pub struct CheckpointMgr {
    dir: PathBuf,
    keep: usize,
}

impl CheckpointMgr {
    pub fn open(dir: impl Into<PathBuf>, keep: usize) -> Result<CheckpointMgr> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
        Ok(CheckpointMgr { dir, keep: keep.max(1) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn snap_path(&self, seq: u64) -> PathBuf {
        self.dir.join(format!("snap-{seq:08}.tlc"))
    }

    /// All snapshots on disk, ascending by sequence number.
    pub fn list(&self) -> Result<Vec<(u64, PathBuf)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("read checkpoint dir {}", self.dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(seq) = name
                .strip_prefix("snap-")
                .and_then(|s| s.strip_suffix(".tlc"))
                .and_then(|s| s.parse::<u64>().ok())
            else {
                continue;
            };
            out.push((seq, entry.path()));
        }
        out.sort_by_key(|&(seq, _)| seq);
        Ok(out)
    }

    /// Persist `snap` as the next numbered snapshot and prune old ones.
    pub fn save(&self, snap: &LeagueSnapshot) -> Result<PathBuf> {
        // the temp name is unique per writer: two concurrent savers (e.g.
        // the background snapshotter and snapshot_now) may race to the
        // same seq, but each renames a complete file — last one wins,
        // and a torn file can never appear under the snap-*.tlc name
        static TMP_NONCE: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let nonce = TMP_NONCE.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let existing = self.list()?;
        let seq = existing.last().map_or(0, |&(s, _)| s + 1);
        let bytes = snap.to_bytes();
        let tmp = self
            .dir
            .join(format!(".snap-{seq:08}.{}-{nonce}.tmp", std::process::id()));
        // fsync before rename: rename-atomicity alone only survives a
        // process crash; a power loss could tear every retained snapshot
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("create {}", tmp.display()))?;
            f.write_all(&bytes)
                .with_context(|| format!("write {}", tmp.display()))?;
            f.sync_all()
                .with_context(|| format!("fsync {}", tmp.display()))?;
        }
        let path = self.snap_path(seq);
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("rename into {}", path.display()))?;
        // persist the rename itself (directory entry)
        if let Ok(d) = std::fs::File::open(&self.dir) {
            d.sync_all().ok();
        }
        // retain the newest `keep` (including the one just written)
        let mut all = existing;
        all.push((seq, path.clone()));
        if all.len() > self.keep {
            for (_, old) in &all[..all.len() - self.keep] {
                std::fs::remove_file(old).ok();
            }
        }
        Ok(path)
    }

    pub fn load(path: &Path) -> Result<LeagueSnapshot> {
        let raw = std::fs::read(path)
            .with_context(|| format!("read snapshot {}", path.display()))?;
        LeagueSnapshot::from_bytes(&raw)
            .with_context(|| format!("decode snapshot {}", path.display()))
    }

    /// Newest *readable* snapshot in the directory, or None if there are
    /// none.  An unreadable newest file (torn by a crash outside this
    /// module, bad disk) is skipped with a warning rather than blocking
    /// resume while intact older snapshots exist.
    pub fn load_latest(&self) -> Result<Option<LeagueSnapshot>> {
        for (_, path) in self.list()?.iter().rev() {
            match Self::load(path) {
                Ok(snap) => return Ok(Some(snap)),
                Err(e) => {
                    eprintln!("checkpoint: skipping unreadable snapshot: {e:#}")
                }
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tleague-ckpt-{}-{tag}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn sample_snapshot() -> LeagueSnapshot {
        let mut payoff = PayoffMatrix::new();
        let mut rng = Pcg32::new(5, 5);
        for _ in 0..50 {
            let a = ModelKey::new(0, rng.below(4));
            let b = ModelKey::new(0, rng.below(4));
            payoff.record(a, b, rng.next_f32());
        }
        let mut hyper =
            HyperMgr::new(vec!["lr".into(), "ent_coef".into()], vec![3e-4, 0.01], 9);
        hyper.set(ModelKey::new(0, 2), vec![1e-3, 0.02]);
        hyper.pbt_enabled = true;
        let models = (0..4)
            .map(|v| ModelBlob {
                key: ModelKey::new(0, v),
                params: (0..32).map(|i| (i as f32) * 0.5 + v as f32).collect(),
                hp: vec![3e-4, 0.01],
                frozen: v < 3,
            })
            .collect();
        LeagueSnapshot {
            pool: (0..3).map(|v| ModelKey::new(0, v)).collect(),
            current: vec![ModelKey::new(0, 3)],
            next_task: 17,
            episodes: 42,
            frames: 4200,
            n_opponents: 1,
            game_mgr: "pfsp".into(),
            rng: Pcg32::from_label(7, "league").state_parts(),
            payoff,
            hyper,
            models,
        }
    }

    #[test]
    fn snapshot_roundtrip_bit_exact() {
        let snap = sample_snapshot();
        let bytes = snap.to_bytes();
        let back = LeagueSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(bytes, back.to_bytes(), "decode+re-encode changed bytes");
        assert_eq!(back.pool, snap.pool);
        assert_eq!(back.current, snap.current);
        assert_eq!(back.models.len(), 4);
        assert_eq!(back.models[1].params, snap.models[1].params);
    }

    #[test]
    fn save_load_and_retention() {
        let dir = tmp_dir("retain");
        let mgr = CheckpointMgr::open(&dir, 3).unwrap();
        assert!(mgr.load_latest().unwrap().is_none(), "empty dir has no snapshot");
        let mut snap = sample_snapshot();
        for i in 0..5u64 {
            snap.episodes = i;
            mgr.save(&snap).unwrap();
        }
        let listed = mgr.list().unwrap();
        assert_eq!(listed.len(), 3, "older snapshots pruned");
        assert_eq!(listed.last().unwrap().0, 4);
        let latest = mgr.load_latest().unwrap().unwrap();
        assert_eq!(latest.episodes, 4);
        // no temp files left behind
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name();
            assert!(
                !name.to_string_lossy().ends_with(".tmp"),
                "stale temp file {name:?}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_shard_models_dedupes_and_sorts() {
        let blob = |agent, version, val: f32| ModelBlob {
            key: ModelKey::new(agent, version),
            params: vec![val; 4],
            hp: vec![3e-4],
            frozen: false,
        };
        // R=2 layout: every blob appears on two of three shards, in
        // arbitrary per-shard order
        let merged = merge_shard_models(vec![
            vec![blob(1, 2, 12.0), blob(0, 1, 1.0)],
            vec![blob(0, 2, 2.0), blob(1, 2, 12.0)],
            vec![blob(0, 1, 1.0), blob(0, 2, 2.0)],
        ]);
        let keys: Vec<ModelKey> = merged.iter().map(|b| b.key).collect();
        assert_eq!(
            keys,
            vec![
                ModelKey::new(0, 1),
                ModelKey::new(0, 2),
                ModelKey::new(1, 2)
            ]
        );
        assert_eq!(merged[2].params, vec![12.0; 4]);
        // shard-layout independence: a different grouping yields the
        // same bytes
        let other = merge_shard_models(vec![
            vec![blob(0, 1, 1.0), blob(0, 2, 2.0), blob(1, 2, 12.0)],
            vec![],
        ]);
        assert_eq!(merged, other);
    }

    #[test]
    fn rejects_corrupt_and_foreign_files() {
        assert!(LeagueSnapshot::from_bytes(b"not a snapshot").is_err());
        // right magic, wrong format version
        let mut buf = Vec::new();
        buf.put_u32(SNAP_MAGIC);
        buf.put_u32(SNAP_FORMAT + 1);
        assert!(LeagueSnapshot::from_bytes(&buf).is_err());
        // truncated valid snapshot
        let bytes = sample_snapshot().to_bytes();
        assert!(LeagueSnapshot::from_bytes(&bytes[..bytes.len() / 2]).is_err());
    }

    #[test]
    fn load_latest_skips_corrupt_newest() {
        let dir = tmp_dir("fallback");
        let mgr = CheckpointMgr::open(&dir, 5).unwrap();
        let mut snap = sample_snapshot();
        snap.episodes = 7;
        mgr.save(&snap).unwrap();
        // a newer snapshot torn by something outside CheckpointMgr
        std::fs::write(dir.join("snap-00000009.tlc"), b"garbage").unwrap();
        let loaded = mgr.load_latest().unwrap().expect("older snapshot usable");
        assert_eq!(loaded.episodes, 7, "must fall back to the intact snapshot");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sequence_continues_after_reopen() {
        let dir = tmp_dir("reopen");
        let snap = sample_snapshot();
        {
            let mgr = CheckpointMgr::open(&dir, 5).unwrap();
            mgr.save(&snap).unwrap();
            mgr.save(&snap).unwrap();
        }
        let mgr = CheckpointMgr::open(&dir, 5).unwrap();
        let path = mgr.save(&snap).unwrap();
        assert!(
            path.to_string_lossy().ends_with("snap-00000002.tlc"),
            "{path:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
