//! Message transport: the ZeroMQ-substitute (§3.3 of the paper).
//!
//! Three socket patterns TLeague uses, over length-prefixed TCP frames:
//!   - REQ/REP  — task requests, ModelPool read/write (`ReqClient`/`RepServer`)
//!   - PUSH/PULL — actor→learner trajectory streaming (`PushClient`/`PullServer`)
//!   - (PUB/SUB is folded into REQ/REP polling: ModelPool reads are cheap)
//!
//! Frame format: u32 little-endian length + payload (a `Wire`-encoded
//! `Msg`).  Every server spawns one thread per connection; this repo's
//! scale (tens of actors per learner per machine) does not need epoll.

use crate::proto::Msg;
use crate::util::codec::Wire;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write as IoWrite};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub const MAX_FRAME: u32 = 512 << 20; // 512 MiB guard (synthetic params are 25 MiB)

/// Write one length-prefixed frame.
pub fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    let len = payload.len() as u32;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(payload)?;
    Ok(())
}

/// Read one length-prefixed frame into `buf` (reused across calls).
pub fn read_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<()> {
    let mut len_bytes = [0u8; 4];
    stream.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes);
    if len > MAX_FRAME {
        bail!("frame too large: {len}");
    }
    buf.resize(len as usize, 0);
    stream.read_exact(buf)?;
    Ok(())
}

/// Blocking request/response client with lazy (re)connect.
pub struct ReqClient {
    addr: String,
    stream: Mutex<Option<TcpStream>>,
}

impl ReqClient {
    pub fn connect(addr: &str) -> ReqClient {
        ReqClient { addr: addr.to_string(), stream: Mutex::new(None) }
    }

    /// Send `msg`, wait for the reply.  Reconnects (with retry/backoff)
    /// on broken connections — the k8s-restart story of the paper means
    /// peers can briefly vanish.
    pub fn request(&self, msg: &Msg) -> Result<Msg> {
        let payload = msg.to_bytes();
        let mut guard = self.stream.lock().unwrap();
        let mut last_err = None;
        for attempt in 0..40 {
            if guard.is_none() {
                match TcpStream::connect(&self.addr) {
                    Ok(s) => {
                        s.set_nodelay(true).ok();
                        *guard = Some(s);
                    }
                    Err(e) => {
                        last_err = Some(e.into());
                        drop(guard);
                        std::thread::sleep(Duration::from_millis(
                            25 * (attempt + 1).min(10),
                        ));
                        guard = self.stream.lock().unwrap();
                        continue;
                    }
                }
            }
            let stream = guard.as_mut().unwrap();
            let ok = write_frame(stream, &payload).and_then(|_| {
                let mut buf = Vec::new();
                read_frame(stream, &mut buf)?;
                Msg::from_bytes(&buf)
            });
            match ok {
                Ok(reply) => return Ok(reply),
                Err(e) => {
                    *guard = None; // force reconnect
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow::anyhow!("request failed")))
            .with_context(|| format!("req to {}", self.addr))
    }
}

/// Request/response server: spawns a handler thread per connection.
pub struct RepServer {
    pub addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl RepServer {
    /// Bind to `addr` ("127.0.0.1:0" for an ephemeral port) and serve
    /// `handler(msg) -> reply` until `shutdown()`.
    pub fn serve<F>(addr: &str, handler: F) -> Result<RepServer>
    where
        F: Fn(Msg) -> Msg + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handler = Arc::new(handler);
        let handle = std::thread::Builder::new()
            .name(format!("rep@{local}"))
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let h = handler.clone();
                            let stop3 = stop2.clone();
                            std::thread::spawn(move || {
                                Self::conn_loop(stream, h, stop3);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(RepServer { addr: local, stop, handle: Some(handle) })
    }

    fn conn_loop(
        mut stream: TcpStream,
        handler: Arc<dyn Fn(Msg) -> Msg + Send + Sync>,
        stop: Arc<AtomicBool>,
    ) {
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_millis(200)))
            .ok();
        let mut buf = Vec::new();
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match read_frame(&mut stream, &mut buf) {
                Ok(()) => {}
                Err(e) => {
                    // timeouts poll the stop flag; anything else ends the conn
                    if let Some(io) = e.downcast_ref::<std::io::Error>() {
                        if matches!(
                            io.kind(),
                            std::io::ErrorKind::WouldBlock
                                | std::io::ErrorKind::TimedOut
                        ) {
                            continue;
                        }
                    }
                    return;
                }
            }
            let reply = match Msg::from_bytes(&buf) {
                Ok(msg) => handler(msg),
                Err(e) => Msg::Err(format!("decode: {e}")),
            };
            if write_frame(&mut stream, &reply.to_bytes()).is_err() {
                return;
            }
        }
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for RepServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One-way streaming sender (actor side of trajectory PUSH).
pub struct PushClient {
    addr: String,
    stream: Mutex<Option<TcpStream>>,
}

impl PushClient {
    pub fn connect(addr: &str) -> PushClient {
        PushClient { addr: addr.to_string(), stream: Mutex::new(None) }
    }

    pub fn push(&self, msg: &Msg) -> Result<()> {
        let payload = msg.to_bytes();
        let mut guard = self.stream.lock().unwrap();
        for attempt in 0..40 {
            if guard.is_none() {
                match TcpStream::connect(&self.addr) {
                    Ok(s) => {
                        s.set_nodelay(true).ok();
                        *guard = Some(s);
                    }
                    Err(_) => {
                        drop(guard);
                        std::thread::sleep(Duration::from_millis(
                            25 * (attempt + 1).min(10),
                        ));
                        guard = self.stream.lock().unwrap();
                        continue;
                    }
                }
            }
            match write_frame(guard.as_mut().unwrap(), &payload) {
                Ok(()) => return Ok(()),
                Err(_) => *guard = None,
            }
        }
        bail!("push to {} failed", self.addr)
    }
}

/// One-way streaming receiver (learner side of trajectory PULL); frames
/// from all connections are funneled into one bounded queue, giving the
/// blocking-queue backpressure the paper's on-policy mode relies on.
pub struct PullServer {
    pub addr: String,
    rx: std::sync::mpsc::Receiver<Msg>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl PullServer {
    pub fn bind(addr: &str, queue_cap: usize) -> Result<PullServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let (tx, rx) = std::sync::mpsc::sync_channel(queue_cap);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name(format!("pull@{local}"))
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let tx = tx.clone();
                            let stop3 = stop2.clone();
                            std::thread::spawn(move || {
                                Self::conn_loop(stream, tx, stop3);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(PullServer { addr: local, rx, stop, handle: Some(handle) })
    }

    fn conn_loop(
        mut stream: TcpStream,
        tx: std::sync::mpsc::SyncSender<Msg>,
        stop: Arc<AtomicBool>,
    ) {
        stream
            .set_read_timeout(Some(Duration::from_millis(200)))
            .ok();
        let mut buf = Vec::new();
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match read_frame(&mut stream, &mut buf) {
                Ok(()) => {
                    if let Ok(msg) = Msg::from_bytes(&buf) {
                        // blocking send = backpressure to the TCP socket,
                        // which stalls the pushing actor (on-policy mode)
                        if tx.send(msg).is_err() {
                            return;
                        }
                    }
                }
                Err(e) => {
                    if let Some(io) = e.downcast_ref::<std::io::Error>() {
                        if matches!(
                            io.kind(),
                            std::io::ErrorKind::WouldBlock
                                | std::io::ErrorKind::TimedOut
                        ) {
                            continue;
                        }
                    }
                    return;
                }
            }
        }
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<Msg> {
        self.rx.recv_timeout(d).ok()
    }
    pub fn try_recv(&self) -> Option<Msg> {
        self.rx.try_recv().ok()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for PullServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::{ModelKey, TrajSegment};

    #[test]
    fn req_rep_roundtrip() {
        let server = RepServer::serve("127.0.0.1:0", |msg| match msg {
            Msg::Ping => Msg::Pong,
            other => Msg::Err(format!("unexpected {other:?}")),
        })
        .unwrap();
        let client = ReqClient::connect(&server.addr);
        for _ in 0..10 {
            assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        }
    }

    #[test]
    fn req_rep_many_clients() {
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Ok).unwrap();
        let addr = server.addr.clone();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let c = ReqClient::connect(&addr);
                    for _ in 0..50 {
                        assert_eq!(c.request(&Msg::Ping).unwrap(), Msg::Ok);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn push_pull_stream() {
        let server = PullServer::bind("127.0.0.1:0", 64).unwrap();
        let client = PushClient::connect(&server.addr);
        let seg = TrajSegment {
            model_key: ModelKey::new(0, 1),
            t: 2,
            n_agents: 1,
            obs: vec![0.0; 12],
            actions: vec![1, 2],
            behavior_logp: vec![-1.0, -1.0],
            rewards: vec![0.5, -0.5],
            discounts: vec![0.99, 0.0],
        };
        for _ in 0..20 {
            client.push(&Msg::Traj(seg.clone())).unwrap();
        }
        let mut got = 0;
        while got < 20 {
            let msg = server
                .recv_timeout(Duration::from_secs(5))
                .expect("timed out");
            assert!(matches!(msg, Msg::Traj(ref s) if *s == seg));
            got += 1;
        }
    }

    #[test]
    fn client_survives_server_restart() {
        let mut server = RepServer::serve("127.0.0.1:0", |_| Msg::Ok).unwrap();
        let addr = server.addr.clone();
        let client = ReqClient::connect(&addr);
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Ok);
        server.shutdown();
        // old per-connection threads poll the stop flag every 200ms;
        // wait for them to drain before the client reconnects.
        std::thread::sleep(Duration::from_millis(400));
        // restart on the same port
        let _server2 = RepServer::serve(&addr, |_| Msg::Pong).unwrap();
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
    }
}
