//! Readiness polling via direct `epoll` FFI — the same no-dependency
//! style as `util::signal`: hand-declared `extern "C"` bindings instead
//! of a libc crate.  One `Poller` per event-loop thread; a `WakeFd`
//! (eventfd) per loop lets other threads interrupt `wait()` immediately
//! for shutdown or cross-thread reply injection.

use anyhow::{bail, Result};

pub const EPOLLIN: u32 = 0x1;
pub const EPOLLOUT: u32 = 0x4;
pub const EPOLLERR: u32 = 0x8;
pub const EPOLLHUP: u32 = 0x10;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
const EPOLL_CLOEXEC: i32 = 0o2000000;
const EFD_CLOEXEC: i32 = 0o2000000;
const EFD_NONBLOCK: i32 = 0o4000;
const SOL_SOCKET: i32 = 1;
const SO_SNDBUF: i32 = 7;
const SO_RCVBUF: i32 = 8;
const RLIMIT_NOFILE: i32 = 7;

/// Kernel `struct epoll_event`.  Packed on x86_64 (the kernel ABI packs
/// it there); natural layout elsewhere.  Fields of the packed variant
/// must be copied out by value, never borrowed.
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

#[repr(C)]
struct Rlimit {
    rlim_cur: u64,
    rlim_max: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(
        epfd: i32,
        events: *mut EpollEvent,
        maxevents: i32,
        timeout_ms: i32,
    ) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn close(fd: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn setsockopt(
        fd: i32,
        level: i32,
        optname: i32,
        optval: *const u8,
        optlen: u32,
    ) -> i32;
    fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
}

fn os_err(what: &str) -> anyhow::Error {
    anyhow::anyhow!("{what}: {}", std::io::Error::last_os_error())
}

/// One epoll instance.  Tokens are caller-chosen u64s carried in the
/// kernel event payload; `wait` hands back `(token, readiness)` pairs.
pub struct Poller {
    epfd: i32,
}

impl Poller {
    pub fn new() -> Result<Poller> {
        // SAFETY: epoll_create1 takes no pointers; the returned fd is
        // owned by the Poller and closed exactly once in Drop.
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(os_err("epoll_create1"));
        }
        Ok(Poller { epfd })
    }

    fn ctl(&self, op: i32, fd: i32, token: u64, interest: u32) -> Result<()> {
        let mut ev = EpollEvent { events: interest, data: token };
        // SAFETY: `ev` is a live repr(C) value for the duration of the
        // call; the kernel copies it and keeps no reference.
        let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(os_err("epoll_ctl"));
        }
        Ok(())
    }

    pub fn add(&self, fd: i32, token: u64, interest: u32) -> Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, token, interest)
    }

    pub fn modify(&self, fd: i32, token: u64, interest: u32) -> Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, token, interest)
    }

    pub fn del(&self, fd: i32) -> Result<()> {
        // the event argument is ignored for DEL but must be non-null on
        // pre-2.6.9 kernels; pass a zeroed one unconditionally
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait up to `timeout_ms` (-1 = forever), appending `(token,
    /// readiness)` pairs to `out` (cleared first).  EINTR surfaces as an
    /// empty wake so callers re-check their stop conditions.
    pub fn wait(&self, out: &mut Vec<(u64, u32)>, timeout_ms: i32) -> Result<()> {
        out.clear();
        let mut evs = [EpollEvent { events: 0, data: 0 }; 128];
        // SAFETY: `evs` is a stack array of repr(C) events and
        // `maxevents` is its exact length, so the kernel writes in
        // bounds; entries beyond the returned count stay initialized
        // (zeroed above).
        let n = unsafe {
            epoll_wait(self.epfd, evs.as_mut_ptr(), evs.len() as i32, timeout_ms)
        };
        if n < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                return Ok(());
            }
            bail!("epoll_wait: {e}");
        }
        for ev in evs.iter().take(n as usize) {
            let ev = *ev; // copy out of the (possibly packed) array slot
            out.push((ev.data, ev.events));
        }
        Ok(())
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: the Poller exclusively owns `epfd` (never exposed),
        // so this is the single close of a valid descriptor.
        unsafe {
            close(self.epfd);
        }
    }
}

/// Nonblocking eventfd used to interrupt a `Poller::wait` from another
/// thread: register `raw()` under a reserved token, `wake()` from
/// anywhere, `drain()` on the loop thread when the token fires.
pub struct WakeFd {
    fd: i32,
}

impl WakeFd {
    pub fn new() -> Result<WakeFd> {
        // SAFETY: eventfd takes no pointers; the fd is owned by the
        // WakeFd and closed exactly once in Drop.
        let fd = unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) };
        if fd < 0 {
            return Err(os_err("eventfd"));
        }
        Ok(WakeFd { fd })
    }

    pub fn raw(&self) -> i32 {
        self.fd
    }

    /// Signal the owning loop.  Failure modes (counter saturated ⇒
    /// EAGAIN) still leave the fd readable, so errors are ignored.
    pub fn wake(&self) {
        let one = 1u64.to_ne_bytes();
        // SAFETY: writes 8 bytes from a live stack buffer of exactly
        // that size to an fd this WakeFd owns.
        unsafe {
            let _ = write(self.fd, one.as_ptr(), one.len());
        }
    }

    /// Reset the counter so the fd stops polling readable.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        // SAFETY: reads at most 8 bytes into a live stack buffer of
        // exactly that size from an fd this WakeFd owns.
        unsafe {
            let _ = read(self.fd, buf.as_mut_ptr(), buf.len());
        }
    }
}

impl Drop for WakeFd {
    fn drop(&mut self) {
        // SAFETY: the WakeFd exclusively owns `fd`; this is its single
        // close.  `raw()` borrowers are loop-local registrations that
        // are deregistered before the owning Arc drops.
        unsafe {
            close(self.fd);
        }
    }
}

// SAFETY: WakeFd is an immutable i32 fd; eventfd read/write are atomic
// kernel ops, safe from any thread concurrently.
unsafe impl Send for WakeFd {}
// SAFETY: see Send — `wake`/`drain` take &self and race benignly (the
// counter saturates; the fd simply stays readable).
unsafe impl Sync for WakeFd {}

fn set_buf_opt(fd: i32, opt: i32, bytes: usize) -> Result<()> {
    let val = bytes as i32;
    // SAFETY: passes a pointer to a live i32 with its exact size; the
    // kernel copies the value during the call.
    let rc = unsafe {
        setsockopt(
            fd,
            SOL_SOCKET,
            opt,
            (&val as *const i32).cast::<u8>(),
            std::mem::size_of::<i32>() as u32,
        )
    };
    if rc < 0 {
        return Err(os_err("setsockopt"));
    }
    Ok(())
}

/// Shrink/grow a socket's kernel send buffer — the short-write test hook
/// (a tiny SO_SNDBUF forces partial vectored writes on the reply path).
pub fn set_sndbuf(fd: i32, bytes: usize) -> Result<()> {
    set_buf_opt(fd, SO_SNDBUF, bytes)
}

/// Companion receive-buffer knob, used with `set_sndbuf` in tests to
/// bound in-flight bytes from both ends.
pub fn set_rcvbuf(fd: i32, bytes: usize) -> Result<()> {
    set_buf_opt(fd, SO_RCVBUF, bytes)
}

/// Soft RLIMIT_NOFILE — the fd budget a fan-in bench must respect (the
/// 4096-connection row is skipped when this is too low).
pub fn nofile_limit() -> u64 {
    let mut r = Rlimit { rlim_cur: 0, rlim_max: 0 };
    // SAFETY: `r` is a live repr(C) struct the kernel fills in bounds.
    let rc = unsafe { getrlimit(RLIMIT_NOFILE, &mut r) };
    if rc < 0 {
        return 0;
    }
    r.rlim_cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn wakefd_interrupts_wait() {
        let poller = Poller::new().unwrap();
        let wake = std::sync::Arc::new(WakeFd::new().unwrap());
        poller.add(wake.raw(), 7, EPOLLIN).unwrap();
        let w2 = wake.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            w2.wake();
        });
        let mut evs = Vec::new();
        let t0 = std::time::Instant::now();
        poller.wait(&mut evs, 5_000).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].0, 7);
        assert!(t0.elapsed() < std::time::Duration::from_secs(2));
        wake.drain();
        // drained: a zero-timeout wait sees nothing
        poller.wait(&mut evs, 0).unwrap();
        assert!(evs.is_empty());
        t.join().unwrap();
    }

    #[test]
    fn poller_reports_socket_readiness() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut conn, _) = listener.accept().unwrap();
        conn.set_nonblocking(true).unwrap();
        let poller = Poller::new().unwrap();
        poller.add(conn.as_raw_fd(), 42, EPOLLIN).unwrap();
        let mut evs = Vec::new();
        poller.wait(&mut evs, 0).unwrap();
        assert!(evs.is_empty(), "no data yet");
        client.write_all(b"hi").unwrap();
        poller.wait(&mut evs, 2_000).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].0, 42);
        assert!(evs[0].1 & EPOLLIN != 0);
        let mut buf = [0u8; 2];
        conn.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"hi");
        poller.del(conn.as_raw_fd()).unwrap();
    }

    #[test]
    fn nofile_limit_is_sane() {
        let n = nofile_limit();
        assert!(n >= 64, "soft fd limit implausibly low: {n}");
    }
}
