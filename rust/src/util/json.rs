//! Minimal JSON parser + writer (the offline crate set has no serde).
//!
//! Used for: the artifact manifest written by python/compile/aot.py, run
//! configuration files, kube-lite orchestrator specs, and experiment
//! result logs.  Supports the full JSON grammar; numbers are kept as f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

// hand-rolled Display/Error: the offline crate set ships no thiserror
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `obj.path("a.b.c")`
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- builders ----------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json { Json::Num(v) }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json { Json::Num(v as f64) }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json { Json::Num(v as f64) }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json { Json::Bool(v) }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json { Json::Str(v.to_string()) }
}
impl From<String> for Json {
    fn from(v: String) -> Json { Json::Str(v) }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= 1;
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => {
                    match self.bump().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let c = self.bump()
                                    .ok_or_else(|| self.err("bad \\u"))?;
                                code = code * 16
                                    + (c as char).to_digit(16)
                                        .ok_or_else(|| self.err("bad hex"))?;
                            }
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xf0 { 4 } else if c >= 0xe0 { 3 } else { 2 };
                        let end = (start + len).min(self.b.len());
                        if let Ok(frag) = std::str::from_utf8(&self.b[start..end]) {
                            s.push_str(frag);
                            self.pos = end;
                        } else {
                            s.push('\u{fffd}');
                        }
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while matches!(self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{text}'") })
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.path("a").unwrap().as_arr().unwrap()[2]
                       .get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true,"n":null}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse("\"\\u00e9 caf\u{00e9}\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "\u{e9} caf\u{e9}");
    }

    #[test]
    fn builder_api() {
        let j = Json::obj().set("x", 3usize).set("s", "hi");
        assert_eq!(j.get("x").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.to_string(), r#"{"s":"hi","x":3}"#);
    }
}
